"""Batched serving example: prefill a batch of prompts through a small MoE
model, then greedy-decode with the KV-cache decode step (the path the
decode_32k / long_500k dry-run cells lower at production scale).

Run:  PYTHONPATH=src python examples/serve_batched.py --arch granite-moe-3b-a800m-smoke
"""
import argparse
import time

import numpy as np

from repro.configs.base import get_config
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-3b-a800m-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    print(f"serving {cfg.name} (vocab={cfg.vocab_size}, "
          f"{cfg.param_count()/1e6:.1f}M params)")
    eng = ServeEngine(cfg, max_seq=args.max_seq, batch_size=args.batch)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=rng.integers(4, 17)).tolist()
               for _ in range(args.batch)]
    t0 = time.perf_counter()
    res = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0

    for i, (p, row) in enumerate(zip(prompts, res.tokens)):
        print(f"req{i}: prompt[{len(p)} toks] -> {row[:10].tolist()}...")
    tput = (res.prefill_tokens + res.decode_steps * args.batch) / dt
    print(f"\nprefill {res.prefill_tokens} toks + {res.decode_steps} decode "
          f"steps x{args.batch} in {dt:.2f}s  ({tput:.0f} tok/s on CPU)")


if __name__ == "__main__":
    main()
