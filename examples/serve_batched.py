"""Continuous-batching serving example: requests are SUBMITTED at staggered
times while the engine decodes, late arrivals are admitted into slots freed
by finished requests (chunked prefill into the slot's cache region), and the
decode batch advances every live slot at its own position.

Run:  PYTHONPATH=src python examples/serve_batched.py --arch granite-moe-3b-a800m-smoke
"""
import argparse
import time

import numpy as np

from repro.configs.base import get_config
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-3b-a800m-smoke")
    ap.add_argument("--slots", type=int, default=2,
                    help="decode slots — fewer than requests, so the "
                         "example shows mid-flight slot reuse")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--arrival-every", type=int, default=3,
                    help="submit a new request every N decode steps")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    print(f"serving {cfg.name} (vocab={cfg.vocab_size}, "
          f"{cfg.param_count()/1e6:.1f}M params) — {args.slots} slots, "
          f"{args.requests} requests, chunked prefill x{args.chunk}")
    eng = ServeEngine(cfg, max_seq=args.max_seq, batch_size=args.slots,
                      chunk=args.chunk)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(4, 17))).tolist()
               for _ in range(args.requests)]

    # staggered arrivals: one new request every --arrival-every decode
    # steps — late requests land in slots freed by earlier ones
    t0 = time.perf_counter()
    submitted = {}
    next_req = 0
    while next_req < len(prompts) or eng.pending:
        # idle gap in the arrival schedule (everything drained before the
        # next threshold): admit the next request now, decode_steps only
        # advances while slots are live
        if next_req < len(prompts) and (
                not eng.pending or eng.decode_steps >=
                next_req * args.arrival_every):
            rid = eng.submit(prompts[next_req], max_new=args.max_new)
            submitted[rid] = next_req
            print(f"  t={eng.decode_steps:3d} steps: submit req{next_req} "
                  f"[{len(prompts[next_req])} toks]")
            next_req += 1
        was = [None if s is None else s.rid for s in eng.slot_req]
        eng.step()
        for slot, req in enumerate(eng.slot_req):
            if req is not None and was[slot] != req.rid:
                reused = " (reused)" if eng.admissions > args.slots else ""
                print(f"  t={eng.decode_steps:3d} steps: "
                      f"req{submitted[req.rid]} -> slot {slot}{reused}")
    dt = time.perf_counter() - t0

    print()
    for rid, req in sorted(eng.finished.items()):
        i = submitted[rid]
        print(f"req{i}: prompt[{len(prompts[i])} toks] -> "
              f"{req.tokens[:8]}...  ttft {req.ttft_s*1e3:.0f}ms")
    tput = (eng.prefill_tokens + eng.decode_tokens) / dt
    print(f"\n{eng.admissions} admissions into {args.slots} slots, "
          f"{eng.prefill_tokens} prefill toks + {eng.decode_steps} decode "
          f"steps in {dt:.2f}s  ({tput:.0f} tok/s on CPU; prefill "
          f"{eng.prefill_s:.2f}s / decode {eng.decode_s:.2f}s)")


if __name__ == "__main__":
    main()
