"""End-to-end training driver: a ~100M-parameter MoE transformer trained for
a few hundred steps on synthetic data with the full production loop —
grad-accumulation, AdamW + cosine schedule, async checkpointing, restart
safety, straggler monitoring.

Run:  PYTHONPATH=src python examples/train_moe.py --steps 300
CPU note: the default ~100M config takes a few seconds/step on a laptop CPU;
--preset tiny runs the identical loop at toy size for a fast look.
Multi-host: the same driver runs under a mesh — see repro/launch/train.py.
"""
import argparse
import dataclasses
import tempfile

from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, ShapeConfig
from repro.optim.adamw import AdamW, cosine_schedule
from repro.training.trainer import Trainer, TrainerConfig


def model_100m() -> ModelConfig:
    # ~104M params: granite-family MoE at reduced width
    return ModelConfig(
        name="comet-moe-100m", family="moe",
        n_layers=8, d_model=512, d_ff=0, vocab_size=32000,
        attn=AttnConfig(n_heads=8, n_kv_heads=2, head_dim=64,
                        q_block=128, kv_block=128),
        moe=MoEConfig(num_experts=16, top_k=2, d_expert=1024, impl="comet"),
        activation="swiglu", param_dtype="float32", compute_dtype="float32",
        remat="none", tie_embeddings=True)


def model_tiny() -> ModelConfig:
    m = model_100m()
    return dataclasses.replace(
        m, name="comet-moe-tiny", n_layers=2, d_model=128, vocab_size=1024,
        attn=dataclasses.replace(m.attn, n_heads=4, n_kv_heads=2, head_dim=32),
        moe=dataclasses.replace(m.moe, num_experts=8, d_expert=128))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", choices=["100m", "tiny"], default="100m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--impl", default="comet",
                    choices=["comet", "naive", "coarse"])
    args = ap.parse_args()

    cfg = model_100m() if args.preset == "100m" else model_tiny()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl=args.impl))
    n = cfg.param_count()
    print(f"model {cfg.name}: {n/1e6:.1f}M params "
          f"({cfg.active_param_count()/1e6:.1f}M active/token), "
          f"impl={cfg.moe.impl}")

    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="comet_train_")
    tcfg = TrainerConfig(ckpt_dir=ckpt, ckpt_every=50, log_every=10)
    optim = AdamW(lr=cosine_schedule(3e-4, warmup=20, total=args.steps))
    tr = Trainer(cfg, shape, mesh=None, tcfg=tcfg, optim=optim)
    out = tr.run(args.steps)

    ls = [m["loss"] for m in out["metrics"]]
    print(f"\ndone: steps={out['final_step']} restarts={out['restarts']} "
          f"stragglers={len(out['stragglers'])}")
    if ls:
        print(f"loss: {ls[0]:.4f} -> {ls[-1]:.4f} "
              f"(ckpts in {ckpt})")


if __name__ == "__main__":
    main()
