"""Hillclimb driver: compile one (arch × shape) cell with config overrides
and print the three roofline terms — the §Perf iteration tool.

Usage:
  PYTHONPATH=src python tools/hillclimb.py qwen3-moe-235b-a22b train_4k \
      ring_group=4 n_col=2 accum=2 remat=full fsdp=1 chunk=64 impl=comet
"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import json
import time

import jax

from repro.analysis import roofline as RL
from repro.configs.base import LM_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.train_step import (build_decode_step, build_prefill_step,
                                     build_train_step)


def main():
    arch, shape_name = sys.argv[1], sys.argv[2]
    kw = dict(a.split("=", 1) for a in sys.argv[3:])
    cfg = get_config(arch)
    over = {}
    if cfg.moe is not None:
        moe = cfg.moe
        if "impl" in kw:
            moe = dataclasses.replace(moe, impl=kw["impl"])
        if "ring_group" in kw:
            moe = dataclasses.replace(moe, ring_group=int(kw["ring_group"]))
        if "n_col" in kw:
            moe = dataclasses.replace(moe, n_col_blocks=int(kw["n_col"]))
        if "ep" in kw:
            moe = dataclasses.replace(moe, ep=int(kw["ep"]))
        if "cap" in kw:
            moe = dataclasses.replace(moe, capacity_factor=float(kw["cap"]))
        over["moe"] = moe
    if cfg.ssm is not None and "chunk" in kw:
        over["ssm"] = dataclasses.replace(cfg.ssm, chunk_size=int(kw["chunk"]))
    if "remat" in kw:
        over["remat"] = kw["remat"]
    if "spres" in kw:
        over["sp_residual"] = kw["spres"] == "1"
    if "padheads" in kw and cfg.attn is not None:
        over["attn"] = dataclasses.replace(cfg.attn,
                                           pad_heads=kw["padheads"] == "1")
    if "dtype" in kw:
        over["compute_dtype"] = kw["dtype"]
    if over:
        cfg = dataclasses.replace(cfg, **over)

    mesh = make_production_mesh(multi_pod=kw.get("multipod", "0") == "1")
    shape = LM_SHAPES[shape_name]
    accum = int(kw.get("accum", 0))
    fsdp = kw.get("fsdp", "1") == "1"
    seq_shard = kw.get("sp", "1") == "1"

    t0 = time.time()
    if shape.kind == "train":
        built = build_train_step(cfg, shape, mesh, accum=accum, fsdp=fsdp,
                                 seq_shard=seq_shard)
        args = (built["state_abstract"], built["batch_structs"])
    elif shape.kind == "prefill":
        built = build_prefill_step(cfg, shape, mesh, fsdp=fsdp)
        args = (built["params_abstract"], built["batch_structs"])
    else:
        built = build_decode_step(cfg, shape, mesh, fsdp=fsdp)
        args = (built["params_abstract"], built["cache_abstract"],
                built["tok"], built["pos"], built["live"])
    compiled = built["jit"].lower(*args).compile()
    report = RL.analyze(compiled, mesh.devices.size, cfg=cfg, shape=shape)
    report["overrides"] = kw
    report["compile_s"] = time.time() - t0
    print(RL.fmt_report(f"{arch}/{shape_name} {kw}", report))
    if kw.get("save"):
        os.makedirs("experiments/perf", exist_ok=True)
        fn = (f"experiments/perf/{arch}_{shape_name}_"
              + "_".join(f"{k}{v}" for k, v in sorted(kw.items())
                         if k != "save") + ".json")
        with open(fn, "w") as f:
            json.dump(report, f, indent=1)
        print("saved:", fn)


if __name__ == "__main__":
    main()
