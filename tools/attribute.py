"""Attribute per-device FLOPs/bytes/ICI of a compiled cell to op_name buckets.

Usage: PYTHONPATH=src python tools/attribute.py <arch> <shape> [impl]
"""
import os, re, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.configs.base import get_config, LM_SHAPES
from repro.launch.train_step import (build_train_step, build_prefill_step,
                                     build_decode_step)
from repro.launch.mesh import make_production_mesh
from repro.analysis.hlo_cost import (HLOCostModel, _COLLECTIVES, _TRIP_BC,
                                     _COND, _BODY, _CALLS)

KEYWORDS = ("flash", "attn", "rope", "moe", "dispatch", "combine", "xent",
            "logsumexp", "embed", "silu", "gelu", "ssd", "ssm", "conv",
            "adamw", "norm", "transpose")
opname_re = re.compile(r'op_name="([^"]*)"')


def bucket_of(name: str) -> str:
    bwd = "bwd:" if "transpose" in name else ""
    for kw in KEYWORDS[:-1]:
        if kw in name:
            return bwd + kw
    return bwd + "other"


def main():
    arch, shape_name = sys.argv[1], sys.argv[2]
    impl = sys.argv[3] if len(sys.argv) > 3 else ""
    import dataclasses
    cfg = get_config(arch)
    if impl and cfg.moe:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, impl=impl))
    mesh = make_production_mesh()
    shape = LM_SHAPES[shape_name]
    if shape.kind == "train":
        built = build_train_step(cfg, shape, mesh)
        args = (built["state_abstract"], built["batch_structs"])
    elif shape.kind == "prefill":
        built = build_prefill_step(cfg, shape, mesh)
        args = (built["params_abstract"], built["batch_structs"])
    else:
        built = build_decode_step(cfg, shape, mesh)
        args = (built["params_abstract"], built["cache_abstract"],
                built["tok"], built["pos"], built["live"])
    c = built["jit"].lower(*args).compile()
    m = HLOCostModel(c.as_text())

    # computation multiplicities via while walk (fusion-called comps excluded
    # from byte attribution on purpose — bytes counted at call sites)
    mult = {m.entry: 1.0}
    def walk(cn, mul):
        comp = m.comps.get(cn)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.op == "while":
                trip = 1
                mm = _TRIP_BC.search(ins.attrs)
                if mm:
                    trip = int(mm.group(1))
                for rx in (_COND, _BODY):
                    mb = rx.search(ins.attrs)
                    if mb:
                        mult[mb.group(1)] = mult.get(mb.group(1), 0) + mul * trip
                        walk(mb.group(1), mul * trip)
    walk(m.entry, 1.0)

    fl, by, ici = {}, {}, {}
    for cn, mul in mult.items():
        comp = m.comps[cn]
        for ins in comp.instrs:
            mm = opname_re.search(ins.attrs)
            key = bucket_of(mm.group(1)) if mm else "?"
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                ob = m._operand_bytes(comp, ins)
                ici[key] = ici.get(key, 0.0) + ob * mul
                continue
            if ins.op == "fusion":
                cm = _CALLS.search(ins.attrs)
                if cm:
                    sub = m.comp_cost(cm.group(1))
                    fl[key] = fl.get(key, 0.0) + sub.flops * mul
                by[key] = by.get(key, 0.0) + \
                    (m._operand_bytes(comp, ins) + ins.nbytes) * mul
            elif ins.op == "dot":
                fl[key] = fl.get(key, 0.0) + m._dot_flops(comp, ins) * mul
                by[key] = by.get(key, 0.0) + \
                    (m._operand_bytes(comp, ins) + ins.nbytes) * mul
            elif ins.op not in ("parameter", "constant", "tuple",
                                "get-tuple-element", "bitcast", "reshape"):
                by[key] = by.get(key, 0.0) + \
                    (m._operand_bytes(comp, ins) + ins.nbytes) * mul

    print(f"{'bucket':16s} {'GFLOP':>10s} {'GB':>10s} {'ici GB':>10s}")
    keys = sorted(set(fl) | set(by) | set(ici),
                  key=lambda k: -(by.get(k, 0) + ici.get(k, 0)))
    for k in keys[:20]:
        print(f"{k:16s} {fl.get(k,0)/1e9:10.1f} {by.get(k,0)/2**30:10.2f} "
              f"{ici.get(k,0)/2**30:10.2f}")


if __name__ == "__main__":
    main()
