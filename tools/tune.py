"""Offline adaptive-plan tuner: populates the JSON plan cache that
moe_layer / train_step / serving resolve transport schedules from.

Two modes:

* model-backed (default) — ranks every candidate plan with the analytical
  cost model (analysis/simulator.py + roofline terms); needs no devices.
  Tunes the paper's Table-2 model shapes over an M grid, plus the smoke
  shape that `benchmarks/run.py --plan` executes for real.
* --measured — times REAL shard_map executions of the MoE layer on a
  forced-host-device mesh (or attached accelerators) and caches the argmin.

Usage:
  PYTHONPATH=src python tools/tune.py --hw tpu_v5e
  PYTHONPATH=src python tools/tune.py --hw tpu_v5e --out plans/tpu_v5e.json \
      --M 1024 4096 16384 --ep 8
  PYTHONPATH=src python tools/tune.py --hw tpu_v5e --measured --devices 8 \
      --arch granite-moe-3b-a800m-smoke --batch 4 --seq 32
"""
import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _print_plan(tag, s, plan):
    sched = f",{plan.schedule}/ns{plan.n_slices}" if plan.schedule else ""
    print(f"{tag},M{s.M},N{s.N},K{s.K},E{s.E},k{s.topk},ep{s.ep},etp{s.etp},"
          f"{plan.phase},{plan.impl},rg{plan.ring_group},nc{plan.n_col_blocks},"
          f"ig{plan.intra_group},{plan.wire_dtype},"
          f"{plan.gemm_impl},fc{int(plan.fused_combine)},"
          f"{plan.measured_s * 1e3:.4f}ms,{plan.source}{sched}")


def _hw_lines():
    """One readable line per Hardware preset, topology descriptor included
    — what the unknown---hw error prints so the fix is self-evident."""
    from repro.core.adaptive import HW
    lines = []
    for name in sorted(HW):
        h = HW[name]
        topo = (f"intra_bw={h.intra_bw / 1e9:.0f}GB/s "
                f"inter_bw={h.inter_bw / 1e9:.0f}GB/s "
                f"intra_group={h.intra_group}"
                if h.intra_group > 1 else "flat")
        lines.append(f"  {name:<16} link_bw={h.link_bw / 1e9:.0f}GB/s "
                     f"hop_latency={h.hop_latency_s * 1e6:.0f}us  {topo}")
    return "\n".join(lines)


# the (arch, B, S) of the single-device smoke run `benchmarks/run.py --plan`
# executes for real; its plan-shape key is tuned below so the demo run hits
# the cache
SMOKE_ARCH = "granite-moe-3b-a800m-smoke"
SMOKE_BATCH_SEQ = (2, 16)


def smoke_plan_shapes():
    from repro.configs.base import get_config
    from repro.core.adaptive import plan_shape
    cfg = get_config(SMOKE_ARCH)
    toks = SMOKE_BATCH_SEQ[0] * SMOKE_BATCH_SEQ[1]
    return [("granite-smoke", cfg.moe,
             plan_shape(cfg.moe, cfg.d_model, toks, 1, 1))]


def tune_model_backed(args, hw, cache):
    from benchmarks.figures import PAPER_MODELS
    from repro.core.adaptive import MoEShape, candidate_plans, tune_plan

    # --graph widens the candidate set with whole-graph scheduled variants
    # (schedule="overlap", n_slices in {2,4}) so block-schedule IR plans
    # rank in the SAME cache rows as per-layer overlap plans
    def cands(s):
        return candidate_plans(s, include_graph=True) if args.graph else None

    n = 0
    for phase in args.phase:
        Ms = args.decode_M if phase == "decode" else args.M
        for name, m in PAPER_MODELS.items():
            for M in Ms:
                s = MoEShape(M=M, N=m["N"], K=m["K"] // max(1, args.etp),
                             E=m["E"], topk=m["topk"], ep=args.ep,
                             etp=args.etp)
                plan = tune_plan(s, hw, cache, force=args.force, phase=phase,
                                 candidates=cands(s))
                _print_plan(name, s, plan)
                n += 1
        for tag, _mcfg, s in smoke_plan_shapes():
            plan = tune_plan(s, hw, cache, force=args.force, phase=phase,
                             candidates=cands(s))
            _print_plan(tag, s, plan)
            n += 1
    return n


def tune_measured(args, hw, cache):
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.core.adaptive import (candidate_plans, make_timing_measure,
                                     plan_shape, tune_plan)
    from repro.core.moe_layer import pack_expert_weights
    from repro.models.common import is_glu
    from repro.parallel.compat import make_mesh
    from repro.parallel.mesh import AxisCtx

    cfg = get_config(args.arch)
    mcfg = cfg.moe
    if mcfg is None:
        raise SystemExit(f"--measured requires a MoE arch, got {args.arch}")
    E, d, f = mcfg.num_experts, cfg.d_model, mcfg.d_expert

    n_dev = len(jax.devices())
    mp = args.ep * args.etp
    if mp > n_dev or E % args.ep or f % args.etp:
        raise SystemExit(f"ep={args.ep} etp={args.etp} needs {mp} devices "
                         f"(have {n_dev}) and must divide E={E}, f={f}")
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    full = {"w_up": jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.05,
            "w_down": jax.random.normal(ks[2], (E, f, d), jnp.float32) * 0.05}
    if is_glu(cfg.activation):
        full["w_gate"] = \
            jax.random.normal(ks[0], (E, d, f), jnp.float32) * 0.05
    router_w = jax.random.normal(ks[3], (d, E), jnp.float32) * 0.1
    x = jax.random.normal(ks[4], (args.batch, args.seq, d), jnp.float32)

    if mp > 1:
        dp = max(1, n_dev // mp)
        mesh = make_mesh((dp, mp), ("data", "model"))
        ctx = AxisCtx(mesh=mesh, dp_axes=("data",), model_axis="model",
                      ep=args.ep, etp=args.etp)
        experts = pack_expert_weights(full, args.ep, args.etp)
    else:
        ctx = AxisCtx()
        experts = {k: v[None] for k, v in full.items()}
    params = {"router": router_w, "experts": experts}

    # no-drop capacity: every candidate computes identical work
    mcfg = dataclasses.replace(mcfg, capacity_factor=float(E))
    # time the full fwd+bwd step (the v3 ranking objective) unless asked not
    # to, and key the plan with the SAME token resolution moe_ffn uses
    phase = args.phase[0] if args.phase else "train"
    fwd_only = args.fwd_only or phase != "train"
    measure = make_timing_measure(cfg, mcfg, params, x, ctx,
                                  iters=args.iters, warmup=1,
                                  grad=not fwd_only)
    from repro.core.moe_layer import local_token_count
    toks = local_token_count(ctx, args.batch, args.seq)
    s = plan_shape(mcfg, d, toks, ctx.ep, ctx.etp)
    cands = candidate_plans(s, gemm_impls=tuple(args.gemm))
    plan = tune_plan(s, hw, cache, measure=measure, candidates=cands,
                     force=args.force, phase=phase,
                     objective="fwd" if (args.fwd_only and phase == "train")
                     else None)
    _print_plan(args.arch, s, plan)
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--hw", default="tpu_v5e")
    ap.add_argument("--out", default=None,
                    help="plan-cache path (default plans/<hw>.json)")
    ap.add_argument("--M", type=int, nargs="*", default=[1024, 4096, 16384],
                    help="per-group token counts to tune (model mode)")
    ap.add_argument("--phase", nargs="*", default=["train"],
                    choices=["train", "prefill", "decode"],
                    help="latency phases to tune plans for; train ranks "
                         "fwd+bwd, prefill/decode rank forward-only "
                         "(serving). --measured uses the first entry")
    ap.add_argument("--decode-M", type=int, nargs="*",
                    default=[8, 32, 128, 512],
                    help="token counts for the decode phase (per-step "
                         "batch sizes, not sequence chunks)")
    ap.add_argument("--ep", type=int, default=8)
    ap.add_argument("--etp", type=int, default=1)
    ap.add_argument("--force", action="store_true",
                    help="re-tune even on a cache hit")
    ap.add_argument("--graph", action="store_true",
                    help="also rank whole-graph block-schedule candidates "
                         "(schedule=overlap, micro-sliced) against the "
                         "per-layer plans (model mode)")
    ap.add_argument("--measured", action="store_true",
                    help="time real executions instead of the cost model")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count (--measured)")
    ap.add_argument("--arch", default="granite-moe-3b-a800m-smoke",
                    help="MoE arch to time (--measured)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--fwd-only", action="store_true",
                    help="time only the forward (--measured); default times "
                         "the full fwd+bwd step, matching the v3 objective")
    ap.add_argument("--gemm", nargs="*", default=["xla", "pallas_fused"],
                    choices=["xla", "pallas", "pallas_fused"],
                    help="GroupGEMM backends to search (--measured). The "
                         "model-backed mode always searches xla + "
                         "pallas_fused (it can rank those via the hidden-"
                         "HBM-traffic term, but not xla vs pallas)")
    args = ap.parse_args(argv)

    if args.measured:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    from repro.core.adaptive import HW, PlanCache
    if args.hw not in HW:
        raise SystemExit(
            f"unknown --hw {args.hw!r}; available Hardware presets:\n"
            + _hw_lines())
    hw = HW[args.hw]
    out = args.out or os.path.join("plans", f"{args.hw}.json")
    cache = PlanCache(out)

    print("tag,M,N,K,E,topk,ep,etp,phase,impl,ring_group,n_col,intra_group,"
          "wire,gemm,fused_combine,latency,source")
    if args.measured:
        tune_measured(args, hw, cache)
    else:
        tune_model_backed(args, hw, cache)
    cache.save()
    print(f"\nwrote {len(cache.plans)} plans -> {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
