#!/usr/bin/env python
"""comet-verify driver: run the static-analysis passes over the repo.

    python tools/verify.py --all            # every pass, text output
    python tools/verify.py --all --json     # machine-readable (CI)
    python tools/verify.py --schedule       # race detector only
    python tools/verify.py --kernels        # Pallas resource checker only
    python tools/verify.py --conventions    # AST linter only

Exit status 1 iff any error-severity diagnostic is produced. The
schedule pass lowers every MoE arch in ``configs/archs.py`` and
re-derives hazards for its overlap orders; the kernel pass checks the
built-in kernel models, the candidate_plans VMEM property and the
legalize fixed point; the conventions pass lints ``src/repro``.
"""
import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default if none selected)")
    ap.add_argument("--schedule", action="store_true",
                    help="schedule-IR race detector")
    ap.add_argument("--kernels", action="store_true",
                    help="Pallas VMEM/bounds/dtype checker")
    ap.add_argument("--conventions", action="store_true",
                    help="hot-path convention linter")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON")
    ap.add_argument("--root", default=os.path.join(_ROOT, "src", "repro"),
                    help="tree to lint (conventions pass)")
    args = ap.parse_args(argv)
    if not (args.schedule or args.kernels or args.conventions):
        args.all = True

    from repro.analysis.verify.diagnostics import Report
    report = Report()

    if args.all or args.schedule:
        from repro.analysis.verify import schedule_check
        report.extend(schedule_check.check_model_archs())
    if args.all or args.kernels:
        from repro.analysis.verify import kernel_check
        report.extend(kernel_check.check_builtin_kernels())
        report.extend(kernel_check.check_candidate_plans())
        report.extend(kernel_check.check_legalize_fixed_point())
    if args.all or args.conventions:
        from repro.analysis.verify import conventions
        report.extend(conventions.lint_tree(args.root))

    print(report.to_json() if args.json else report.text())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
