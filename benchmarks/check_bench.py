"""BENCH-artifact gate: the named assertions CI (and anyone locally) runs
against the ``benchmarks.run --json`` output, extracted from the old inline
``python -c`` blobs so both the tier1 and serving jobs — and a laptop —
share ONE set of checks with readable failure messages.

Usage:
    python benchmarks/check_bench.py --bench BENCH_pr6.json
    python benchmarks/check_bench.py --bench out.json --only serving paged
    python benchmarks/check_bench.py --list
Exit code: 0 iff every (selected) gate passes.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Callable, List, Tuple

# (name, bound, source figure, check). ``bound`` is the human-readable
# inequality the gate enforces; ``figure`` names the benchmarks.run section
# (and the paper figure it reproduces) the gate reads — both surface in
# ``--list`` so a red CI run can be mapped to a figure without reading code.
Gate = Tuple[str, str, str, Callable[[dict], Tuple[bool, str]]]


def _rows(d: dict) -> List[Tuple[str, dict]]:
    return sorted(d.items())


def g_micro(d):
    m = d["micro"]
    bad = [k for k, r in _rows(m) if not r["best_s"] > 0]
    return not bad and bool(m), f"non-positive timings: {bad}" if bad else \
        f"{len(m)} kernel microbenchmarks present"


def g_hbm_fused(d):
    rows = _rows(d["hbm_hot_path"])
    if not rows:                       # empty section must FAIL, not pass
        return False, "hbm_hot_path has no rows (figure not run?)"
    bad = [k for k, r in rows if not r["fused_bytes"] < r["unfused_bytes"]]
    return (not bad,
            f"fused >= unfused HBM bytes at {bad}" if bad else
            f"fused below unfused HBM bytes at all {len(rows)} shapes")


def g_bwd_hbm(d):
    rows = _rows(d["bwd_overlap"])
    if not rows:
        return False, "bwd_overlap has no rows (figure not run?)"
    bad = [k for k, r in rows
           if not r["hbm_bwd_custom_bytes"] < r["hbm_bwd_autodiff_bytes"]]
    return (not bad,
            f"custom backward HBM not below autodiff at {bad}" if bad else
            f"comet backward HBM below autodiff at all {len(rows)} shapes")


def g_bwd_exposed(d):
    rows = _rows(d["bwd_overlap"])
    if not rows:
        return False, "bwd_overlap has no rows (figure not run?)"
    bad = [k for k, r in rows
           if not r["exposed_comm_custom_s"] < r["exposed_comm_autodiff_s"]]
    return (not bad,
            f"custom exposed comm not below autodiff at {bad}" if bad else
            f"comet exposed comm below autodiff at all {len(rows)} shapes")


def g_decode_plans(d):
    dp = d["serving"]["decode_plans"]
    if not dp["rows"]:
        return False, "decode_plans has no rows (figure not run?)"
    ok = bool(dp["tuned_no_slower_than_naive"])
    return ok, (f"tuned decode plan no slower than naive at all "
                f"{len(dp['rows'])} shapes" if ok
                else "a tuned decode plan is slower than naive")


def g_trace(d):
    t = d["serving"]["trace"]
    bad = [k for k in ("ttft_s_mean", "tokens_per_s", "decode_tok_latency_s")
           if not t[k] > 0]
    return (not bad,
            f"non-positive serving-trace metrics: {bad}" if bad else
            "Poisson-trace TTFT / throughput / decode latency all positive")


def g_paged_capacity(d):
    c = d["serving"]["paged"]["capacity"]
    r = c["capacity_ratio_equal_mem"]
    return (r >= 1.5,
            f"paged capacity {r:.2f}x contiguous at equal cache memory "
            f"(gate: >= 1.5x; mean budget "
            f"{c['mean_request_budget_tokens']:.0f} toks of "
            f"max_seq {c['max_seq']})")


def g_paged_parity(d):
    t = d["serving"]["paged"]["trace"]
    ok = bool(t["bit_exact_vs_contiguous"])
    return ok, ("paged engine bit-exact vs contiguous on the trace" if ok
                else "paged engine DIVERGED from the contiguous reference")


def g_paged_concurrency(d):
    t = d["serving"]["paged"]["trace"]
    p, c = t["peak_live_paged"], t["peak_live_contiguous"]
    return (p > c,
            f"peak live requests at equal memory: paged {p} vs "
            f"contiguous {c}")


def g_batched_admission(d):
    a = d["serving"]["paged"]["admission"]
    s, b = a["sequential_rounds"], a["batched_rounds"]
    return (b < s,
            f"admission burst of {a['burst_requests']}: {b} stacked "
            f"call(s) batched vs {s} sequential")


def g_chaos_terminal(d):
    c = d["serving"]["chaos"]
    ok = bool(c["all_terminal"])
    return ok, (f"all {c['n_requests']} requests terminal under "
                f"{c['failures']} injected failures / "
                f"{c['recoveries']} recoveries" if ok else
                "a request was left non-terminal under faults")


def g_chaos_exactly_once(d):
    c = d["serving"]["chaos"]
    ok = (bool(c["streams_bit_identical"]) and c["lost_tokens"] == 0
          and c["duplicated_tokens"] == 0)
    return ok, (f"token streams bit-identical to fault-free, "
                f"0 lost / 0 duplicated ({c['quarantined']} quarantined "
                f"kept clean prefixes)" if ok else
                f"delivery broke: identical={c['streams_bit_identical']} "
                f"lost={c['lost_tokens']} dup={c['duplicated_tokens']}")


def g_chaos_ttft(d):
    c = d["serving"]["chaos"]
    f = c["ttft_p99_factor"]
    return (0 < f <= 25.0,
            f"p99 TTFT under faults {c['ttft_p99_s_faulted']*1e3:.1f}ms = "
            f"{f:.1f}x fault-free (gate: <= 25x)")


def g_disagg_parity(d):
    g = d["serving"]["disagg"]
    ok = bool(g["trace"]["bit_exact_vs_shared_engine"])
    return ok, (f"disagg token streams bit-exact vs the shared engine on "
                f"the {g['n_requests']}-request mixed-length Poisson trace"
                if ok else
                "disagg topology DIVERGED from the shared-engine reference")


def g_disagg_ttft(d):
    t = d["serving"]["disagg"]["ttft"]
    s, m = t["shared_mean_ticks"], t["disagg_mean_ticks"]
    return (m < s,
            f"mean TTFT at equal total slots: disagg {m:.2f} ticks vs "
            f"shared {s:.2f} (prefill admission decoupled from decode "
            f"turnover)" if m < s else
            f"disagg mean TTFT {m:.2f} ticks NOT below shared {s:.2f}")


def g_disagg_exactly_once(d):
    c = d["serving"]["disagg"]["crash"]
    ok = (bool(c["all_terminal"]) and bool(c["streams_bit_identical"])
          and c["lost_tokens"] == 0 and c["duplicated_tokens"] == 0
          and c["recoveries"] >= c["injected_crashes"] > 0)
    return ok, (f"{c['injected_crashes']} single-worker crashes "
                f"({', '.join(sorted(c['plan'].values()))}) -> "
                f"{c['recoveries']} recoveries, streams bit-identical, "
                f"0 lost / 0 duplicated across the handoff boundary" if ok
                else f"worker-crash delivery broke: terminal="
                     f"{c['all_terminal']} identical="
                     f"{c['streams_bit_identical']} lost={c['lost_tokens']} "
                     f"dup={c['duplicated_tokens']} recoveries="
                     f"{c['recoveries']}/{c['injected_crashes']}")


def g_disagg_migration(d):
    m = d["serving"]["disagg"]["migration"]
    ok = (m["migrations"] == d["serving"]["disagg"]["n_requests"]
          and m["pages_moved"] == m["expected_content_pages"]
          and m["decode_worker_prefill_tokens"] == 0)
    return ok, (f"{m['migrations']} handoffs moved exactly the "
                f"{m['pages_moved']} content pages (no tail-budget "
                f"copies), decode workers ran 0 prefill tokens" if ok else
                f"migration unbounded: {m['migrations']} handoffs, "
                f"{m['pages_moved']} pages vs "
                f"{m['expected_content_pages']} expected, "
                f"{m['decode_worker_prefill_tokens']} decode-side "
                f"prefill tokens (re-prefill!)")


def g_whole_graph(d):
    rows = _rows(d["whole_graph"])
    if not rows:
        return False, "whole_graph has no rows (figure not run?)"
    bad = [k for k, r in rows
           if not (r["scheduled_fwd_s"] < r["baseline_fwd_s"]
                   and r["scheduled_step_s"] < r["baseline_step_s"])]
    return (not bad,
            f"scheduled e2e not strictly below layer-at-a-time at {bad}"
            if bad else
            f"scheduled e2e strictly below layer-at-a-time baseline "
            f"(fwd and fwd+bwd) at all {len(rows)} paper models")


def g_hier_modeled(d):
    rows = _rows(d["hier_transport"]["modeled"])
    if not rows:
        return False, "hier_transport.modeled has no rows (figure not run?)"
    bad = [k for k, r in rows
           if not (r["hier_exposed_s"] < r["flat_exposed_s"]
                   and r["hier_bwd_exposed_s"] <= r["flat_bwd_exposed_s"])]
    return (not bad,
            f"hier exposed comm not below flat at {bad}" if bad else
            f"hier modeled exposed comm strictly below flat comet at all "
            f"{len(rows)} paper shapes (bwd <= too)")


def g_hier_measured(d):
    m = d["hier_transport"].get("measured")
    if not m:
        return False, ("hier_transport.measured missing (8-device census "
                       "subprocess failed?)")
    ef, eh = m["flat"]["exposed_s"], m["hier"]["exposed_s"]
    parity = d["hier_transport"].get("flat_hier_parity_rel", 1.0)
    if m["hier"]["intra_hops"] <= 0:
        return False, "hier execution censused no intra-class hops"
    if not parity < 1e-5:
        return False, f"flat/hier fp32 outputs diverge (rel {parity:.1e})"
    return (eh < ef,
            f"census-measured exposed: hier {eh * 1e6:.1f}us vs flat "
            f"{ef * 1e6:.1f}us ({m['hier']['intra_hops']} hops repriced "
            f"intra-class)" if eh < ef else
            f"measured hier exposed {eh * 1e6:.1f}us NOT below flat "
            f"{ef * 1e6:.1f}us")


def g_hier_wire(d):
    rows = _rows(d["hier_transport"].get("wire", {}))
    if not rows:
        return False, "hier_transport.wire has no rows (figure not run?)"
    if not d["hier_transport"]["wire"].get("bf16", {}).get("available"):
        return False, "bf16 wire row missing/unavailable"
    bad = [k for k, r in rows if r.get("available")
           and not r["max_rel_err"] <= r["tol"]]
    avail = [k for k, r in rows if r.get("available")]
    return (not bad,
            f"wire error beyond documented tolerance at {bad}" if bad else
            f"{avail} within documented tolerance of the fp32 wire "
            f"(fp32 accumulation)")


def g_hier_rotation(d):
    ok = d["hier_transport"].get("rotation_deterministic")
    return (bool(ok),
            "encoded wire payloads bit-identical across ring rotations"
            if ok else "wire payload bytes CHANGED under ring rotation")


GATES: List[Gate] = [
    ("micro_present", "best_s > 0 for every kernel",
     "micro (Fig. 8 kernel sweep)", g_micro),
    ("hbm_fused_below_unfused", "fused_bytes < unfused_bytes",
     "hbm_hot_path (Fig. 6 fused combine)", g_hbm_fused),
    ("bwd_hbm_below_autodiff", "hbm_bwd_custom < hbm_bwd_autodiff",
     "bwd_overlap (Fig. 7 backward ring)", g_bwd_hbm),
    ("bwd_exposed_comm_below_autodiff",
     "exposed_comm_custom_s < exposed_comm_autodiff_s",
     "bwd_overlap (Fig. 7 backward ring)", g_bwd_exposed),
    ("serving_decode_plans_tuned", "tuned decode <= naive decode",
     "serving.decode_plans (Table 4 latency)", g_decode_plans),
    ("serving_trace_positive", "ttft/throughput/latency > 0",
     "serving.trace (Poisson trace)", g_trace),
    ("paged_capacity_headroom", "capacity_ratio_equal_mem >= 1.5",
     "serving.paged.capacity (PR5 paged KV)", g_paged_capacity),
    ("paged_trace_parity", "bit_exact_vs_contiguous == true",
     "serving.paged.trace (PR5 paged KV)", g_paged_parity),
    ("paged_peak_concurrency", "peak_live_paged > peak_live_contiguous",
     "serving.paged.trace (PR5 paged KV)", g_paged_concurrency),
    ("batched_admission_fewer_calls", "batched_rounds < sequential_rounds",
     "serving.paged.admission (PR5 paged KV)", g_batched_admission),
    ("whole_graph_scheduled_below_baseline",
     "scheduled_{fwd,step}_s < baseline_{fwd,step}_s",
     "whole_graph (PR6 block-schedule IR)", g_whole_graph),
    ("serving_chaos_all_terminal", "every request reaches terminal status",
     "serving.chaos (PR7 fault tolerance)", g_chaos_terminal),
    ("serving_chaos_exactly_once",
     "bit-identical streams, 0 lost, 0 duplicated",
     "serving.chaos (PR7 fault tolerance)", g_chaos_exactly_once),
    ("serving_chaos_ttft_bounded", "ttft_p99_factor <= 25",
     "serving.chaos (PR7 fault tolerance)", g_chaos_ttft),
    ("disagg_stream_parity", "bit_exact_vs_shared_engine == true",
     "serving.disagg (PR10 router/worker topology)", g_disagg_parity),
    ("disagg_ttft_below_shared",
     "disagg_mean_ticks < shared_mean_ticks at equal total slots",
     "serving.disagg (PR10 router/worker topology)", g_disagg_ttft),
    ("disagg_exactly_once_under_worker_crash",
     "bit-identical streams, 0 lost / 0 dup, recoveries >= crashes",
     "serving.disagg (PR10 router/worker topology)", g_disagg_exactly_once),
    ("disagg_migration_bounded",
     "pages_moved == content pages, decode prefill tokens == 0",
     "serving.disagg (PR10 router/worker topology)", g_disagg_migration),
    ("hier_exposed_below_flat_modeled",
     "hier_exposed_s < flat_exposed_s (bwd <=)",
     "hier_transport.modeled (PR9 two-level ring)", g_hier_modeled),
    ("hier_exposed_below_flat_measured",
     "census-priced hier exposed < flat, fp32 parity exact",
     "hier_transport.measured (PR9 two-level ring)", g_hier_measured),
    ("hier_wire_tolerance", "max_rel_err <= documented tol per wire dtype",
     "hier_transport.wire (PR9 wire format)", g_hier_wire),
    ("hier_wire_rotation_deterministic",
     "encoded payloads bit-identical across rotations",
     "hier_transport.wire (PR9 wire format)", g_hier_rotation),
]


def _list_gates() -> int:
    w = max(len(n) for n, _, _, _ in GATES)
    for name, bound, figure, _ in GATES:
        print(f"{name:<{w}}  {bound}  [{figure}]")
    print(f"\n{len(GATES)} gates")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench",
                    help="path to the benchmarks.run --json artifact")
    ap.add_argument("--only", nargs="*", default=None,
                    help="run only gates whose name contains any of these "
                         "substrings (default: all)")
    ap.add_argument("--list", action="store_true",
                    help="print every gate with its bound and source "
                         "figure, then exit 0")
    args = ap.parse_args(argv)
    if args.list:
        return _list_gates()
    if not args.bench:
        ap.error("--bench is required unless --list is given")
    try:
        with open(args.bench) as f:
            d = json.load(f)
    except (OSError, ValueError) as e:
        print(f"[FAIL] cannot read BENCH artifact {args.bench!r}: {e}")
        return 1

    if args.only is not None:
        # every --only token must hit at least one gate: a typo'd selector
        # silently running zero checks is how a gate rots out of CI
        avail = [n for n, _, _, _ in GATES]
        dead = [s for s in args.only
                if not any(s in n for n in avail)]
        if dead:
            print(f"[FAIL] --only token(s) {dead} matched no gate; "
                  f"available: {avail}")
            return 1
    gates = [(n, g) for n, _, _, g in GATES
             if args.only is None or any(s in n for s in args.only)]
    fails = 0
    for name, gate in gates:
        try:
            ok, detail = gate(d)
        except KeyError as e:
            ok, detail = False, f"artifact missing key {e} (figure not run?)"
        print(f"[{'PASS' if ok else 'FAIL'}] {name}: {detail}")
        fails += 0 if ok else 1
    print(f"\n{len(gates) - fails}/{len(gates)} BENCH gates passed "
          f"({args.bench})")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
