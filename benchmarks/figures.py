"""One benchmark per paper table/figure, driven by the discrete-event
simulator (H100/L20 constants for validation against the paper's claims) and
by the dry-run roofline JSONs (TPU target).

Each function prints a CSV block and returns a dict of derived headline
numbers; benchmarks/run.py validates them against the paper's reported bands.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.analysis.simulator import (H100_NVL, L20_PCIE, MECHANISMS,
                                      MoEShape, sim_comet, sim_e2e,
                                      sim_fastermoe, sim_megatron,
                                      sim_tutel)
from repro.configs.base import get_config

# the paper's Table 2 models
PAPER_MODELS = {
    "mixtral-8x7b": dict(L=32, E=8, topk=2, N=4096, K=14336),
    "qwen2-moe-2.7b": dict(L=24, E=64, topk=4, N=2048, K=1408),
    "phi3.5-moe": dict(L=32, E=16, topk=2, N=4096, K=6400),
}

BASELINES = ["megatron_cutlass", "megatron_te", "fastermoe", "tutel"]


def _shape(m, M, ep=8, etp=1):
    return MoEShape(M=M, N=m["N"], K=m["K"], E=m["E"], topk=m["topk"],
                    ep=ep, etp=etp)


def _layer(mech: str, hw, s, imb=0.0) -> Dict:
    if mech == "comet":
        return sim_comet(hw, s, imb)
    if mech == "fastermoe" and s.etp > 1:
        return None
    return MECHANISMS[mech](hw, s, imb)


# ---------------------------------------------------------------------------
# Figure 1a — time breakdown of MoE models (comm share of execution)
# ---------------------------------------------------------------------------

def fig1a_breakdown() -> Dict:
    print("\n# fig1a_time_breakdown (Megatron, H100, M=16384, EP=8)")
    print("model,comm_share")
    shares = []
    for name, m in PAPER_MODELS.items():
        s = _shape(m, 16384)
        r = sim_megatron(H100_NVL, s)
        e2e = sim_e2e(H100_NVL, "megatron_cutlass", s, m["N"], m["L"], 8)
        moe_comm = m["L"] * r["comm"]
        share = moe_comm / e2e
        shares.append(share)
        print(f"{name},{share:.3f}")
    avg = sum(shares) / len(shares)
    print(f"average,{avg:.3f}")
    return {"avg_comm_share": avg}


# ---------------------------------------------------------------------------
# Figure 9 — end-to-end model latency
# ---------------------------------------------------------------------------

def fig9_end_to_end() -> Dict:
    print("\n# fig9_end_to_end_latency_ms (H100, W=8)")
    print("model,M,parallelism,mech,ms")
    speedups = []
    for name, m in PAPER_MODELS.items():
        for M in (4096, 8192):
            for (ep, etp) in [(8, 1), (4, 2)]:
                s = _shape(m, M, ep, etp)
                ts = {}
                for mech in BASELINES + ["comet"]:
                    if mech == "fastermoe" and etp > 1:
                        continue
                    t = sim_e2e(H100_NVL, mech, s, m["N"], m["L"],
                                tp_nonmoe=etp if etp > 1 else 1)
                    ts[mech] = t
                    print(f"{name},{M},EP{ep}xTP{etp},{mech},{t*1e3:.2f}")
                for b in BASELINES:
                    if b in ts:
                        speedups.append(ts[b] / ts["comet"])
    avg = sum(speedups) / len(speedups)
    print(f"# e2e speedup vs baselines: avg={avg:.2f} "
          f"min={min(speedups):.2f} max={max(speedups):.2f} (paper: 1.71x)")
    return {"e2e_avg_speedup": avg, "e2e_min": min(speedups),
            "e2e_max": max(speedups)}


# ---------------------------------------------------------------------------
# Figure 10 — single MoE layer vs input token length
# ---------------------------------------------------------------------------

def fig10_single_layer() -> Dict:
    m = PAPER_MODELS["mixtral-8x7b"]
    print("\n# fig10_single_layer_us (Mixtral expert shapes, EP=8, H100)")
    print("M,mech,us")
    speedups = []
    fused_ratios = []
    from repro.core import adaptive as A
    for M in (1024, 2048, 4096, 8192, 16384, 32768, 65536):
        s = _shape(m, M)
        ts = {}
        for mech in BASELINES + ["comet"]:
            r = _layer(mech, H100_NVL, s)
            ts[mech] = r["total"]
            print(f"{M},{mech},{r['total']*1e6:.1f}")
        for b in BASELINES:
            speedups.append(ts[b] / ts["comet"])
        # fused-pipeline schedule variant: same comet overlap, hidden kept
        # in VMEM + streaming combine (fused runs n_col=1 — its early tile
        # completion comes from the kernel's n_major traversal) — modeled
        # with the plan cost model so the HBM-traffic saving is visible
        # next to the paper's numbers
        sa = A.MoEShape(M=M, N=m["N"], K=m["K"], E=m["E"], topk=m["topk"],
                        ep=8, etp=1)
        t_unf = A.modeled_plan_time(H100_NVL, sa, A.Plan("comet", 1, 4, "xla"))
        t_fus = A.modeled_plan_time(
            H100_NVL, sa, A.Plan("comet", 1, 1, "pallas_fused",
                                 fused_combine=True))
        hbm_unf = A.hot_path_hbm_bytes(sa, A.Plan("comet", 1, 4, "xla"))
        hbm_fus = A.hot_path_hbm_bytes(
            sa, A.Plan("comet", 1, 1, "pallas_fused", fused_combine=True))
        fused_ratios.append(t_unf / t_fus)
        print(f"{M},comet_fused,{t_fus*1e6:.1f}")
        print(f"# comet_fused@M{M}: vs comet_planmodel {t_unf*1e6:.1f}us, "
              f"hbm {hbm_fus/2**20:.0f}MB vs {hbm_unf/2**20:.0f}MB")
    avg = sum(speedups) / len(speedups)
    favg = sum(fused_ratios) / len(fused_ratios)
    print(f"# layer speedup: avg={avg:.2f} min={min(speedups):.2f} "
          f"max={max(speedups):.2f} (paper: 1.28-2.37x, avg 1.96x)")
    print(f"# fused-pipeline schedule vs unfused comet (plan model): "
          f"avg {favg:.2f}x")
    return {"layer_avg_speedup": avg, "layer_min": min(speedups),
            "layer_max": max(speedups), "fused_vs_comet_avg": favg,
            "fused_min": min(fused_ratios)}


# ---------------------------------------------------------------------------
# Figure 11 — time breakdown / latency hiding of a single MoE layer
# ---------------------------------------------------------------------------

def fig11_latency_hiding() -> Dict:
    m = PAPER_MODELS["mixtral-8x7b"]
    s = _shape(m, 16384)
    print("\n# fig11_latency_hiding (EP=8 TP=1 E=8 topk=2 M=16384)")
    print("mech,total_us,comm_us,hidden_frac")
    out = {}
    for mech in ("megatron_te", "fastermoe", "tutel", "comet"):
        r = _layer(mech, H100_NVL, s)
        hid = r["overlapped"] / max(r["comm"], 1e-12)
        out[mech] = hid
        print(f"{mech},{r['total']*1e6:.1f},{r['comm']*1e6:.1f},{hid:.3f}")
    print("# paper: comet 86.5%, tutel 68.6%, fastermoe 29.2%")
    return {"hiding": out}


# ---------------------------------------------------------------------------
# Figure 12 — parallelism strategies within the MoE layer
# ---------------------------------------------------------------------------

def fig12_parallelism() -> Dict:
    m = PAPER_MODELS["mixtral-8x7b"]
    print("\n# fig12_parallelism (M=8192, EPxTP=8)")
    print("parallelism,mech,us")
    comet_ts, base_worst = [], []
    for ep, etp in [(8, 1), (4, 2), (2, 4), (1, 8)]:
        s = _shape(m, 8192, ep, etp)
        row = {}
        for mech in BASELINES + ["comet"]:
            r = _layer(mech, H100_NVL, s)
            if r is None:
                continue
            row[mech] = r["total"]
            print(f"EP{ep}xTP{etp},{mech},{r['total']*1e6:.1f}")
        comet_ts.append(row["comet"])
        base_worst.append(min(v for k, v in row.items() if k != "comet"))
    # paper: baselines degrade as TP grows; comet stays low
    degrade_comet = max(comet_ts) / min(comet_ts)
    degrade_base = max(base_worst) / min(base_worst)
    print(f"# degradation over TP sweep: comet {degrade_comet:.2f}x, "
          f"best-baseline {degrade_base:.2f}x")
    return {"degrade_comet": degrade_comet, "degrade_base": degrade_base}


# ---------------------------------------------------------------------------
# Figure 13 — various E and topk
# ---------------------------------------------------------------------------

def fig13_experts_topk() -> Dict:
    m = PAPER_MODELS["mixtral-8x7b"]
    print("\n# fig13_E_topk (M=16384, EP=8, TP=1)")
    print("E,topk,mech,us")
    speedups = []
    for E in (8, 16, 32):
        for topk in (2, 4, 8):
            s = MoEShape(M=16384, N=m["N"], K=m["K"], E=E, topk=topk,
                         ep=8, etp=1)
            ts = {}
            for mech in BASELINES + ["comet"]:
                r = _layer(mech, H100_NVL, s)
                ts[mech] = r["total"]
                print(f"{E},{topk},{mech},{r['total']*1e6:.1f}")
            for b in BASELINES:
                speedups.append(ts[b] / ts["comet"])
    print(f"# speedup range {min(speedups):.2f}-{max(speedups):.2f} "
          f"(paper: 1.16-1.83x vs baselines)")
    return {"etopk_min": min(speedups), "etopk_max": max(speedups)}


# ---------------------------------------------------------------------------
# Figure 14 — imbalanced token distribution + L20 cluster
# ---------------------------------------------------------------------------

def fig14_imbalance_and_l20() -> Dict:
    m = PAPER_MODELS["mixtral-8x7b"]
    print("\n# fig14a_imbalance (E=8 topk=2 M=8192 EP=8)")
    print("std,mech,us")
    mono = {}
    for std in (0.0, 0.02, 0.032, 0.05):
        s = _shape(m, 8192)
        for mech in ("megatron_cutlass", "tutel", "comet"):
            r = _layer(mech, H100_NVL, s, imb=std)
            mono.setdefault(mech, []).append(r["total"])
            print(f"{std},{mech},{r['total']*1e6:.1f}")
    print("\n# fig14b_l20 (E=8 topk=4 M=8192, EPxTP=8)")
    print("parallelism,mech,us")
    speedups = []
    for ep, etp in [(8, 1), (4, 2)]:
        s = _shape(m, 8192, ep, etp)
        s = MoEShape(M=8192, N=m["N"], K=m["K"], E=8, topk=4, ep=ep, etp=etp)
        ts = {}
        for mech in BASELINES + ["comet"]:
            r = _layer(mech, L20_PCIE, s)
            if r is None:
                continue
            ts[mech] = r["total"]
            print(f"EP{ep}xTP{etp},{mech},{r['total']*1e6:.1f}")
        for b in BASELINES:
            if b in ts:
                speedups.append(ts[b] / ts["comet"])
    avg = sum(speedups) / len(speedups)
    print(f"# L20 speedup avg={avg:.2f} (paper: 1.19-1.46x)")
    imb_monotone = all(mono[mech][-1] >= mono[mech][0] * 0.999
                       for mech in mono)
    comet_best_imb = all(
        mono["comet"][i] <= min(mono["megatron_cutlass"][i], mono["tutel"][i])
        for i in range(4))
    return {"l20_avg_speedup": avg, "imb_monotone": imb_monotone,
            "comet_best_under_imbalance": comet_best_imb}


# ---------------------------------------------------------------------------
# Table 3 — communication buffer memory
# ---------------------------------------------------------------------------

def table3_buffers() -> Dict:
    """The paper's NVSHMEM symmetric buffer is 2·M·N bytes. Our ppermute ring
    double-buffers one (M/ep·topk, N) chunk per direction — report both."""
    print("\n# table3_comm_buffer_MB")
    print("model,M,paper_nvshmem_MB,ours_ring_MB")
    out = {}
    for name, m in PAPER_MODELS.items():
        for M in (4096, 8192):
            paper = 2 * M * m["N"] / 2**20
            s = _shape(m, M)
            chunk = (M / 8) * m["topk"] * m["N"] * 2 / 2**20
            ours = 2 * chunk                       # send+recv double buffer
            out[(name, M)] = (paper, ours)
            print(f"{name},{M},{paper:.0f},{ours:.0f}")
    return {"buffers": {f"{k[0]}@{k[1]}": v for k, v in out.items()}}


# ---------------------------------------------------------------------------
# TPU roofline summary (from the dry-run artifacts) — deliverable (g)
# ---------------------------------------------------------------------------

def roofline_summary(dryrun_dir: str = "experiments/dryrun") -> Dict:
    print(f"\n# roofline_summary ({dryrun_dir})")
    print("arch,shape,chips,impl,t_compute_ms,t_memory_ms,t_collective_ms,"
          "dominant,roofline_fraction")
    rows = []
    for fn in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        base = os.path.basename(fn)[:-5].rsplit("_", 2)
        arch_shape = base[0]
        print(f"{arch_shape},{r['n_chips']},{r.get('impl','-')},"
              f"{r['t_compute_s']*1e3:.2f},{r['t_memory_s']*1e3:.2f},"
              f"{r['t_collective_s']*1e3:.2f},{r['dominant']},"
              f"{r.get('roofline_fraction', 0):.4f}")
        rows.append(r)
    if not rows:
        print("# (no dry-run artifacts found — run repro.launch.dryrun)")
    return {"n_cells": len(rows)}


ALL = [fig1a_breakdown, fig9_end_to_end, fig10_single_layer,
       fig11_latency_hiding, fig12_parallelism, fig13_experts_topk,
       fig14_imbalance_and_l20, table3_buffers, roofline_summary]
