"""Benchmark harness: one function per paper table/figure (simulator-driven
H100/L20 validation) + the TPU roofline summary from the dry-run artifacts.

Prints each figure's CSV, then a validation block checking the headline
numbers against the bands the paper reports. Exit code reflects validation.

Run:  PYTHONPATH=src python -m benchmarks.run                 # figures
      PYTHONPATH=src python -m benchmarks.run --tune          # populate plans
      PYTHONPATH=src python -m benchmarks.run --plan plans/tpu_v5e.json
      PYTHONPATH=src python -m benchmarks.run --json BENCH_pr5.json
The --plan mode resolves each shape's transport schedule from the tuned plan
cache (missing file/entry → the analytical model), reports the tuned plan's
modeled latency against the non-overlapped naive baseline, and executes one
real moe_layer forward with the cache-resolved schedule.
The --json mode additionally writes machine-readable per-figure results,
kernel microbenchmarks (dispatch build / combine / fused MLP and its
dgrad/wgrad backward kernels — real timed executions), the modeled hot-path
HBM bytes of the fused vs unfused schedule, the fwd+bwd step figures (the
custom-VJP comet backward ring vs the XLA-autodiff transposed baseline),
and the SERVING figure set: decode-phase plan quality, TTFT / per-token
decode latency / tokens-per-second from a real Poisson-arrival
continuous-batching trace, and the PAGED-cache figures (capacity at equal
cache memory, peak live concurrency + bit-exactness vs the contiguous
engine, batched-vs-sequential admission latency) — the perf-trajectory
artifact. The --json PATH names the artifact; CI gates it with
benchmarks/check_bench.py (one $BENCH variable names produce/gate/upload).
"""
from __future__ import annotations

import argparse
import sys


def validate(results) -> int:
    checks = []

    def chk(name, cond, detail):
        checks.append((name, bool(cond), detail))

    r = results["fig1a_breakdown"]
    chk("fig1a comm share ~47%", 0.25 <= r["avg_comm_share"] <= 0.65,
        f"avg={r['avg_comm_share']:.2f} (paper 0.47)")

    r = results["fig9_end_to_end"]
    chk("fig9 e2e speedup ~1.71x", 1.25 <= r["e2e_avg_speedup"] <= 2.2,
        f"avg={r['e2e_avg_speedup']:.2f} (paper 1.71)")

    r = results["fig10_single_layer"]
    chk("fig10 layer speedup ~1.96x", 1.4 <= r["layer_avg_speedup"] <= 2.6,
        f"avg={r['layer_avg_speedup']:.2f} (paper 1.96)")
    chk("fig10 layer speedup band", r["layer_min"] >= 1.0,
        f"min={r['layer_min']:.2f} (paper min 1.28)")

    h = results["fig11_latency_hiding"]["hiding"]
    chk("fig11 comet hides most latency", h["comet"] >= 0.75,
        f"comet={h['comet']:.2f} (paper 0.865)")
    chk("fig11 ordering comet>tutel>fastermoe",
        h["comet"] > h["tutel"] > h["fastermoe"],
        f"{h['comet']:.2f} > {h['tutel']:.2f} > {h['fastermoe']:.2f} "
        "(paper 0.865/0.686/0.292)")

    r = results["fig12_parallelism"]
    chk("fig12 comet robust across EPxTP",
        r["degrade_comet"] < r["degrade_base"],
        f"comet {r['degrade_comet']:.2f}x vs baseline "
        f"{r['degrade_base']:.2f}x over the TP sweep")

    r = results["fig13_experts_topk"]
    chk("fig13 speedup band ~1.16-1.83x",
        r["etopk_min"] >= 0.95 and r["etopk_max"] <= 3.5,
        f"range {r['etopk_min']:.2f}-{r['etopk_max']:.2f}")

    r = results["fig14_imbalance_and_l20"]
    chk("fig14 imbalance prolongs all systems", r["imb_monotone"], "")
    chk("fig14 comet best under imbalance", r["comet_best_under_imbalance"],
        "")
    chk("fig14 L20 speedup ~1.19-1.46x", 1.0 <= r["l20_avg_speedup"] <= 1.9,
        f"avg={r['l20_avg_speedup']:.2f}")

    r = results["roofline_summary"]
    chk("roofline artifacts present", r["n_cells"] >= 30,
        f"{r['n_cells']} cells")

    print("\n#### validation vs paper claims ####")
    fails = 0
    for name, ok, detail in checks:
        print(f"[{'PASS' if ok else 'FAIL'}] {name} {detail}")
        fails += 0 if ok else 1
    print(f"\n{len(checks) - fails}/{len(checks)} validation checks passed")
    return fails


def run_tune(hw_name: str, out: str, Ms, ep: int) -> int:
    """Model-backed tuning over the paper shapes — same cache format as
    tools/tune.py (which also offers measured tuning)."""
    import tools.tune as TT
    argv = ["--hw", hw_name, "--out", out, "--ep", str(ep), "--M"]
    argv += [str(m) for m in Ms]
    return TT.main(argv)


def _smoke_problem():
    """A tiny real MoE problem (CPU-runnable) sharing tools/tune.py's smoke
    plan-shape key."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from tools.tune import SMOKE_ARCH, SMOKE_BATCH_SEQ

    cfg = get_config(SMOKE_ARCH)
    mcfg = cfg.moe
    E, d, f = mcfg.num_experts, cfg.d_model, mcfg.d_expert
    B, S = SMOKE_BATCH_SEQ
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    params = {
        "router": jax.random.normal(ks[3], (d, E), jnp.float32) * 0.1,
        "experts": {
            "w_gate": jax.random.normal(ks[0], (1, E, d, f), jnp.float32) * 0.05,
            "w_up": jax.random.normal(ks[1], (1, E, d, f), jnp.float32) * 0.05,
            "w_down": jax.random.normal(ks[2], (1, E, f, d), jnp.float32) * 0.05,
        },
    }
    x = jax.random.normal(ks[4], (B, S, d), jnp.float32)
    return cfg, mcfg, params, x


def run_with_plan(cache_path: str, hw_name: str, Ms, ep: int) -> int:
    """Report tuned plans vs the naive baseline and run moe_layer once with
    the cache-resolved schedule. Exit 0 iff a comet plan is at least as fast
    as naive on some bandwidth-bound config."""
    import dataclasses

    import numpy as np

    from benchmarks.figures import PAPER_MODELS
    from repro.core import adaptive as A

    hw = A.HW[hw_name]
    cache = A.load_plan_cache(cache_path)
    print(f"# tuned plans from {cache_path!r} ({len(cache.plans)} entries; "
          f"missing entries use the analytical model)")
    print("model,M,impl,ring_group,n_col,source,t_plan_ms,t_naive_ms,speedup")
    comet_ok = False
    for name, m in PAPER_MODELS.items():
        for M in Ms:
            s = A.MoEShape(M=M, N=m["N"], K=m["K"], E=m["E"], topk=m["topk"],
                           ep=ep, etp=1)
            plan = cache.get(s, hw) or A.analytic_plan(s, hw)
            t_plan = A.modeled_plan_time(hw, s, plan)
            t_naive = A.modeled_plan_time(hw, s, A.Plan("naive"))
            sp = t_naive / t_plan
            if plan.impl == "comet" and sp >= 1.0:
                comet_ok = True
            print(f"{name},{M},{plan.impl},{plan.ring_group},"
                  f"{plan.n_col_blocks},{plan.source},{t_plan * 1e3:.3f},"
                  f"{t_naive * 1e3:.3f},{sp:.2f}")

    # real execution: the smoke MoE layer picks its schedule from the cache
    # (plan_hw pins the lookup to the reported hardware key)
    from repro.core.moe_layer import moe_ffn
    from repro.parallel.mesh import AxisCtx
    cfg, mcfg, params, x = _smoke_problem()
    mcfg = dataclasses.replace(mcfg, plan_cache=cache_path, plan_hw=hw_name)
    toks = x.shape[0] * x.shape[1]
    plan = A.resolve_plan(mcfg, cfg.d_model, toks, 1, 1)
    y, aux = moe_ffn(cfg, mcfg, params, x, AxisCtx())
    finite = bool(np.isfinite(np.asarray(y)).all())
    print(f"\nmoe_layer smoke run under plan [{plan.impl} "
          f"rg{plan.ring_group} nc{plan.n_col_blocks} src={plan.source}]: "
          f"out_norm={float(np.linalg.norm(np.asarray(y))):.4f} "
          f"finite={finite}")
    print(f"[{'PASS' if comet_ok else 'FAIL'}] comet plan >= naive on a "
          "bandwidth-bound config")
    return 0 if (comet_ok and finite) else 1


def kernel_microbench(reps: int = 5):
    """Wall-clock microbenchmarks of the hot-path pieces on tiny CPU-runnable
    shapes (Pallas kernels in interpret mode — the numbers track relative
    code-path cost across PRs, not TPU throughput)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import routing as R
    from repro.core import transport as T
    from repro.kernels import ops

    T_, k, E, d, f = 512, 2, 8, 256, 128
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 7)
    x = jax.random.normal(ks[0], (T_, d), jnp.float32)
    scores = jax.random.normal(ks[1], (T_, E), jnp.float32)
    _, idx = jax.lax.top_k(scores, k)
    C = R.capacity(T_, k, E, float(E))
    w = {"w_gate": jax.random.normal(ks[2], (E, d, f)) * 0.05,
         "w_up": jax.random.normal(ks[3], (E, d, f)) * 0.05,
         "w_down": jax.random.normal(ks[4], (E, f, d)) * 0.05}
    rows = jax.random.normal(ks[5], (E, C, d), jnp.float32)
    buf, info = R.build_dispatch(x, idx, E, C)
    wts = jnp.full((T_, k), 1.0 / k, jnp.float32)

    def timed(fn, *a):
        out = jax.block_until_ready(fn(*a))            # compile
        best = float("inf")
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            out = jax.block_until_ready(fn(*a))
            best = min(best, time.perf_counter() - t0)
        del out
        return best

    # jit returns only the buffer — DispatchInfo holds static ints (not a
    # pytree); the info arrays are traced into the same graph via combine
    dispatch = jax.jit(lambda xx, ii: R.build_dispatch(xx, ii, E, C)[0])
    combine = jax.jit(lambda rv, ww: R.combine(rv, info, ww, E, C, None, 1))
    fused = jax.jit(lambda rr: ops.fused_mlp(rr, w, "swiglu", interpret=True))
    unfused = jax.jit(lambda rr: T.expert_gemm2(
        T.expert_gemm1(rr, w, "swiglu"), w))
    dy = jax.random.normal(ks[6], (E, C, d), jnp.float32)
    dgrad = jax.jit(lambda rr, dd: ops.fused_mlp_dgrad(
        rr, w, dd, "swiglu", interpret=True))
    wgrad = jax.jit(lambda rr, dd: ops.fused_mlp_wgrad(
        rr, w, dd, "swiglu", interpret=True))
    micro = {
        "dispatch_build": {"best_s": timed(dispatch, x, idx),
                           "shape": f"T{T_} k{k} E{E} d{d} C{C}"},
        "combine": {"best_s": timed(combine, buf.reshape(E * C, d), wts),
                    "shape": f"T{T_} k{k} d{d}"},
        "fused_mlp_interpret": {"best_s": timed(fused, rows),
                                "shape": f"E{E} R{C} d{d} f{f}"},
        "unfused_mlp_xla": {"best_s": timed(unfused, rows),
                            "shape": f"E{E} R{C} d{d} f{f}"},
        "fused_mlp_dgrad_interpret": {"best_s": timed(dgrad, rows, dy),
                                      "shape": f"E{E} R{C} d{d} f{f}"},
        "fused_mlp_wgrad_interpret": {"best_s": timed(wgrad, rows, dy),
                                      "shape": f"E{E} R{C} d{d} f{f}"},
    }
    print("\n# kernel_microbench (CPU; interpret-mode Pallas)")
    for name, r in micro.items():
        print(f"{name},{r['shape']},{r['best_s'] * 1e3:.3f}ms")
    return micro


def hbm_hot_path_table(Ms=(8192,), ep: int = 8, n_col: int = 4):
    """Modeled hot-path HBM bytes at the paper's layer shapes — the
    acceptance artifact for the fused pipeline. Each schedule runs at its
    own operating point: unfused comet N-decomposes via n_col col-sliced
    GEMM2 calls (each re-reading the HBM hidden); the fused schedule keeps
    one kernel call (n_col=1 — early tile completion comes from the
    kernel's n_major traversal, so extra col-sliced calls would only
    re-stream the layer-0 weights)."""
    from benchmarks.figures import PAPER_MODELS
    from repro.core import adaptive as A

    table = {}
    print(f"\n# hbm_hot_path_bytes (comet, EP={ep}; unfused n_col={n_col}, "
          "fused n_col=1)")
    print("model,M,unfused_MB,fused_MB,saving_frac")
    for name, m in PAPER_MODELS.items():
        for M in Ms:
            s = A.MoEShape(M=M, N=m["N"], K=m["K"], E=m["E"], topk=m["topk"],
                           ep=ep, etp=1)
            unfused = A.hot_path_hbm_bytes(
                s, A.Plan("comet", 1, n_col, "xla"))
            fused = A.hot_path_hbm_bytes(
                s, A.Plan("comet", 1, 1, "pallas_fused",
                          fused_combine=True))
            table[f"{name}@M{M}"] = {
                "unfused_bytes": unfused, "fused_bytes": fused,
                "saving_frac": 1.0 - fused / unfused,
            }
            print(f"{name},{M},{unfused / 2**20:.0f},{fused / 2**20:.0f},"
                  f"{1.0 - fused / unfused:.3f}")
    return table


def bwd_overlap_table(Ms=(8192,), ep: int = 8):
    """The PR 3 acceptance artifact: one MoE layer's modeled BACKWARD under
    the custom-VJP comet ring (dY chunks on the reverse permutes overlapping
    per-chunk dgrad/wgrad, hidden rematerialized in VMEM, dW flushed per
    macro-step) vs the XLA-autodiff transposed baseline (every reverse
    ppermute serialized after the forward, hidden re-read from HBM, dW
    accumulator round-tripped per chunk). Backward hot-path HBM bytes and
    exposed reverse-collective time must be STRICTLY below the baseline at
    every paper shape; the fwd+bwd step figure rides along."""
    from benchmarks.figures import PAPER_MODELS
    from repro.core import adaptive as A

    hw = A.TPU_V5E
    table = {}
    print(f"\n# bwd_overlap (custom-VJP comet ring vs autodiff baseline, "
          f"EP={ep})")
    print("model,M,bwd_custom_ms,bwd_autodiff_ms,bwd_speedup,"
          "exposed_custom_ms,exposed_autodiff_ms,hbm_custom_MB,"
          "hbm_autodiff_MB,step_ms,step_autodiff_ms")
    for name, m in PAPER_MODELS.items():
        for M in Ms:
            s = A.MoEShape(M=M, N=m["N"], K=m["K"], E=m["E"], topk=m["topk"],
                           ep=ep, etp=1)
            # the comet ring at its best backward operating point among the
            # configurations that structurally cut backward HBM traffic:
            # ring_group > 1 amortizes the dW flushes, pallas_fused keeps
            # the hidden out of HBM entirely (rg=1 + xla would merely match
            # the baseline's traffic while overlapping its comm)
            plan = min((A.legalize_plan(p, s.N, s.ep)
                        for p in A.candidate_plans(s) if p.impl == "comet"
                        and (p.ring_group > 1
                             or p.gemm_impl == "pallas_fused")),
                       key=lambda p: A.modeled_plan_time_bwd(hw, s, p))
            t_bwd = A.modeled_plan_time_bwd(hw, s, plan)
            t_auto = A.autodiff_bwd_time(hw, s)
            exp_c = A.bwd_exposed_comm_time(hw, s, plan)
            exp_a = 2.0 * s.ep * A.layer_times(hw, s)["t_hop"]
            hbm_c = A.hot_path_hbm_bytes_bwd(s, plan)
            hbm_a = A.autodiff_bwd_hbm_bytes(s)
            t_fwd = A.modeled_plan_time(hw, s, plan)
            step = t_fwd + t_bwd
            step_auto = t_fwd + t_auto
            table[f"{name}@M{M}"] = {
                "bwd_custom_s": t_bwd, "bwd_autodiff_s": t_auto,
                "bwd_speedup": t_auto / t_bwd,
                "exposed_comm_custom_s": exp_c,
                "exposed_comm_autodiff_s": exp_a,
                "hbm_bwd_custom_bytes": hbm_c,
                "hbm_bwd_autodiff_bytes": hbm_a,
                "step_custom_s": step, "step_autodiff_s": step_auto,
            }
            print(f"{name},{M},{t_bwd * 1e3:.3f},{t_auto * 1e3:.3f},"
                  f"{t_auto / t_bwd:.2f},{exp_c * 1e3:.3f},"
                  f"{exp_a * 1e3:.3f},{hbm_c / 2**20:.0f},"
                  f"{hbm_a / 2**20:.0f},{step * 1e3:.3f},"
                  f"{step_auto * 1e3:.3f}")
    ok = all(r["hbm_bwd_custom_bytes"] < r["hbm_bwd_autodiff_bytes"]
             and r["exposed_comm_custom_s"] < r["exposed_comm_autodiff_s"]
             for r in table.values())
    print(f"[{'PASS' if ok else 'FAIL'}] comet backward hot-path HBM bytes "
          "+ exposed comm strictly below the autodiff baseline")
    return table


def whole_graph_table(Ms=(8192,), ep: int = 8, n_blocks: int = 2):
    """The PR 6 acceptance artifact: modeled end-to-end step time over an
    ``n_blocks``-layer window when the block-schedule IR hoists the next
    block's attention (and, in training, the previous layer's wgrad flushes)
    into the comet ring's comm bubbles, vs the layer-at-a-time baseline
    (same segments, hard barrier at every block boundary). Micro-slicing
    (n_slices in {1,2,4}) creates the cross-layer freedom; the best slicing
    is reported. Scheduled time must be STRICTLY below the baseline at every
    paper shape, forward-only and fwd+bwd."""
    from benchmarks.figures import PAPER_MODELS
    from repro.core import adaptive as A
    from repro.core import schedule as SCH

    hw = A.TPU_V5E
    table = {}
    print(f"\n# whole_graph (block-schedule IR vs layer-at-a-time, EP={ep}, "
          f"{n_blocks}-block window)")
    print("model,M,n_slices,base_fwd_ms,sched_fwd_ms,fwd_speedup,"
          "base_step_ms,sched_step_ms,step_speedup")
    for name, m in PAPER_MODELS.items():
        for M in Ms:
            s = A.MoEShape(M=M, N=m["N"], K=m["K"], E=m["E"], topk=m["topk"],
                           ep=ep, etp=1)
            d_model = m["N"]
            plan = min((A.legalize_plan(p, s.N, s.ep)
                        for p in A.candidate_plans(s) if p.impl == "comet"),
                       key=lambda p: A.modeled_plan_time(hw, s, p)
                       + A.modeled_plan_time_bwd(hw, s, p))

            def t(training, scheduled, ns):
                return SCH.graph_step_time(
                    hw, s, plan, d_model=d_model, n_blocks=n_blocks,
                    n_slices=ns, training=training,
                    scheduled=scheduled)["total"]

            base_f = t(False, False, 1)
            base_s = t(True, False, 1)
            ns_best, sch_f = min(((ns, t(False, True, ns))
                                  for ns in (1, 2, 4)), key=lambda kv: kv[1])
            sch_s = min(t(True, True, ns) for ns in (1, 2, 4))
            table[f"{name}@M{M}"] = {
                "n_slices": ns_best,
                "baseline_fwd_s": base_f, "scheduled_fwd_s": sch_f,
                "fwd_speedup": base_f / sch_f,
                "baseline_step_s": base_s, "scheduled_step_s": sch_s,
                "step_speedup": base_s / sch_s,
            }
            print(f"{name},{M},{ns_best},{base_f * 1e3:.3f},"
                  f"{sch_f * 1e3:.3f},{base_f / sch_f:.3f},"
                  f"{base_s * 1e3:.3f},{sch_s * 1e3:.3f},"
                  f"{base_s / sch_s:.3f}")
    ok = all(r["scheduled_fwd_s"] < r["baseline_fwd_s"]
             and r["scheduled_step_s"] < r["baseline_step_s"]
             for r in table.values())
    print(f"[{'PASS' if ok else 'FAIL'}] scheduled e2e step time strictly "
          "below the layer-at-a-time baseline (fwd and fwd+bwd)")
    return table


def hier_transport_table(Ms=(8192,), ep: int = 8):
    """The PR 9 acceptance artifact: on the asymmetric-bandwidth preset
    (H100_CROSSNODE: 4-GPU NVLink nodes joined by cross-node RDMA), the
    two-level ``comet_hier`` ring's exposed communication must be STRICTLY
    below flat comet — both MODELED (per-link-class hop profile through the
    three-resource pipeline, every paper shape) and MEASURED (the ppermute
    census of a real 8-device interpret execution, priced with the same
    topology descriptor — ``benchmarks/hier_measured.py`` in a subprocess
    so it owns XLA_FLAGS). The wire-format rows ride the measured run:
    bf16 / fp8_e4m3 dispatch+combine vs the fp32 wire within documented
    tolerance (fp32 accumulation), encoded payloads bit-identical across
    ring rotations."""
    import json as _json
    import os
    import subprocess

    from benchmarks.figures import PAPER_MODELS
    from repro.core import adaptive as A

    hw = A.H100_CROSSNODE
    table = {"modeled": {}}
    print(f"\n# hier_transport (two-level ring vs flat comet on "
          f"{hw.name}, EP={ep}, intra_group={hw.intra_group})")
    print("model,M,flat_exposed_ms,hier_exposed_ms,exposed_cut,"
          "hier_bwd_exposed_ms,flat_bwd_exposed_ms,wire")
    for name, m in PAPER_MODELS.items():
        for M in Ms:
            s = A.MoEShape(M=M, N=m["N"], K=m["K"], E=m["E"], topk=m["topk"],
                           ep=ep, etp=1)
            flat = min((A.legalize_plan(p, s.N, s.ep)
                        for p in A.candidate_plans(s, hw=hw)
                        if p.impl == "comet"),
                       key=lambda p: A.fwd_exposed_comm_time(hw, s, p))
            hier = min((A.legalize_plan(p, s.N, s.ep)
                        for p in A.candidate_plans(s, hw=hw)
                        if p.impl == "comet_hier"),
                       key=lambda p: A.fwd_exposed_comm_time(hw, s, p))
            ef = A.fwd_exposed_comm_time(hw, s, flat)
            eh = A.fwd_exposed_comm_time(hw, s, hier)
            bf = A.bwd_exposed_comm_time(hw, s, flat)
            bh = A.bwd_exposed_comm_time(hw, s, hier)
            table["modeled"][f"{name}@M{M}"] = {
                "flat_exposed_s": ef, "hier_exposed_s": eh,
                "flat_bwd_exposed_s": bf, "hier_bwd_exposed_s": bh,
                "hier_intra_group": hier.intra_group,
                "hier_wire": hier.wire_dtype,
            }
            print(f"{name},{M},{ef * 1e3:.3f},{eh * 1e3:.3f},"
                  f"{ef / max(eh, 1e-12):.2f}x,{bh * 1e3:.3f},"
                  f"{bf * 1e3:.3f},{hier.wire_dtype}")

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(os.path.dirname(__file__), "..", "src"),
                    env.get("PYTHONPATH")) if p)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.hier_measured"],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if r.returncode == 0:
        table.update(_json.loads(r.stdout))
        ms = table["measured"]
        print(f"measured(8-dev census): flat "
              f"{ms['flat']['exposed_s'] * 1e6:.1f}us "
              f"({ms['flat']['inter_hops']} inter hops) vs hier "
              f"{ms['hier']['exposed_s'] * 1e6:.1f}us "
              f"({ms['hier']['inter_hops']} inter + "
              f"{ms['hier']['intra_hops']} intra), parity rel "
              f"{table['flat_hier_parity_rel']:.1e}")
        for wd, row in table["wire"].items():
            print(f"wire {wd}: " + (
                f"max_rel_err {row['max_rel_err']:.2e} "
                f"(tol {row['tol']:.0e})" if row.get("available")
                else "unavailable in this jax"))
        print(f"rotation-deterministic: {table['rotation_deterministic']}")
    else:
        print(f"measured subprocess FAILED rc={r.returncode}: "
              f"{r.stderr[-500:]}")
    ok = (all(v["hier_exposed_s"] < v["flat_exposed_s"]
              and v["hier_bwd_exposed_s"] <= v["flat_bwd_exposed_s"]
              for v in table["modeled"].values())
          and r.returncode == 0
          and table["measured"]["hier"]["exposed_s"]
          < table["measured"]["flat"]["exposed_s"])
    print(f"[{'PASS' if ok else 'FAIL'}] hier exposed comm strictly below "
          "flat comet (modeled at all paper shapes AND census-measured)")
    return table


def serving_decode_plan_table(Ms=(8, 32, 128, 512), ep: int = 8):
    """Decode-phase plan quality at the paper's layer shapes: the tuned
    decode plan (phase="decode" — ranked on the fwd-only per-step latency
    objective) must be no slower than the naive transport on the modeled
    path at every decode batch size. Tiny M legalizes toward bcast / small
    ring groups — exactly the paper's observation that the right overlap
    schedule depends on the workload shape."""
    from benchmarks.figures import PAPER_MODELS
    from repro.core import adaptive as A

    hw = A.TPU_V5E
    table = {}
    print(f"\n# serving_decode_plans (fwd-only latency objective, EP={ep})")
    print("model,M,impl,ring_group,n_col,gemm,t_decode_ms,t_naive_ms,speedup")
    for name, m in PAPER_MODELS.items():
        for M in Ms:
            s = A.MoEShape(M=M, N=m["N"], K=m["K"], E=m["E"], topk=m["topk"],
                           ep=ep, etp=1)
            plan = A.tune_plan(s, hw, cache=None, phase="decode")
            t_plan = A.modeled_plan_time(hw, s, plan)
            t_naive = A.modeled_plan_time(hw, s, A.Plan("naive"))
            table[f"{name}@M{M}"] = {
                "impl": plan.impl, "ring_group": plan.ring_group,
                "n_col_blocks": plan.n_col_blocks,
                "gemm_impl": plan.gemm_impl,
                "t_decode_s": t_plan, "t_naive_s": t_naive,
                "speedup": t_naive / t_plan,
            }
            print(f"{name},{M},{plan.impl},{plan.ring_group},"
                  f"{plan.n_col_blocks},{plan.gemm_impl},"
                  f"{t_plan * 1e3:.4f},{t_naive * 1e3:.4f},"
                  f"{t_naive / t_plan:.2f}")
    ok = all(r["t_decode_s"] <= r["t_naive_s"] * (1 + 1e-9)
             for r in table.values())
    print(f"[{'PASS' if ok else 'FAIL'}] tuned decode plan no slower than "
          "naive at every decode shape")
    return {"rows": table, "tuned_no_slower_than_naive": ok}


def serving_trace_bench(n_requests: int = 8, slots: int = 2,
                        mean_interarrival_steps: float = 2.0,
                        max_new: int = 8, seed: int = 0):
    """Real continuous-batching run on the smoke MoE arch (CPU): a Poisson
    arrival trace with mixed prompt lengths drives the slot scheduler —
    requests submitted as the decode clock passes their arrival step, late
    arrivals admitted into freed slots via chunked prefill. Reports TTFT,
    per-token decode latency, and end-to-end tokens/s. Wall-clock numbers
    track CPU code-path cost across PRs, not TPU throughput."""
    import numpy as np

    from repro.configs.base import get_config
    from repro.serving import EngineConfig

    cfg = get_config("granite-moe-3b-a800m-smoke")
    eng = EngineConfig(max_seq=64, batch_size=slots, seed=seed,
                       chunk=8).build(cfg)
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_interarrival_steps,
                                         size=n_requests)).astype(int)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(3, 17))).tolist()
               for _ in range(n_requests)]

    import time
    t0 = time.perf_counter()
    nxt = 0
    while nxt < n_requests or eng.pending:
        while nxt < n_requests and arrivals[nxt] <= eng.decode_steps:
            eng.submit(prompts[nxt], max_new=max_new)
            nxt += 1
        if not eng.pending:                      # idle gap in the trace
            eng.submit(prompts[nxt], max_new=max_new)
            nxt += 1
        eng.step()
    wall = time.perf_counter() - t0

    ttfts = [r.ttft_s for r in eng.finished.values()]
    total_new = sum(len(r.tokens) for r in eng.finished.values())
    res = {
        "n_requests": n_requests, "slots": slots,
        "prefill_tokens": eng.prefill_tokens,
        "decode_steps": eng.decode_steps,
        "generated_tokens": total_new,
        "ttft_s_mean": float(np.mean(ttfts)),
        "ttft_s_max": float(np.max(ttfts)),
        "decode_tok_latency_s": eng.decode_s / max(1, eng.decode_tokens),
        "tokens_per_s": (eng.prefill_tokens + total_new) / wall,
        "prefill_s": eng.prefill_s, "decode_s": eng.decode_s,
        "wall_s": wall,
    }
    print(f"\n# serving_trace (Poisson arrivals, {slots} slots, "
          f"{n_requests} requests, CPU smoke arch)")
    print(f"ttft mean {res['ttft_s_mean']*1e3:.1f}ms  per-token decode "
          f"{res['decode_tok_latency_s']*1e3:.1f}ms  "
          f"{res['tokens_per_s']:.0f} tok/s  "
          f"({eng.prefill_tokens} prefill + {total_new} decoded)")
    return res


def paged_capacity_table(max_seq: int = 4096, max_new: int = 128,
                         mem_gib: float = 8.0, page: int = 64,
                         n_requests: int = 4096, seed: int = 0):
    """Memory-headroom math for the paged block-table cache at a real model's
    KV geometry: at EQUAL cache memory, the contiguous layout holds
    ``mem / (max_seq * bytes_per_token)`` requests (every slot owns a full
    max_seq region), while the paged pool admits against each request's OWN
    ``prompt + max_new`` page budget — capacity scales with
    ``max_seq / mean_request_budget``. Deterministic (analytic, no device
    work): the acceptance gate requires >= 1.5x at the mixed-length trace."""
    import numpy as np

    from repro.configs.base import get_config

    cfg = get_config("granite-moe-3b-a800m")
    a = cfg.attn
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "a")
    bpt = n_attn * 2 * a.n_kv_heads * a.head_dim * 2        # bf16 K+V
    mem = int(mem_gib * 2**30)
    contig_slots = mem // (max_seq * bpt)
    pages_total = mem // (page * bpt)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(128, max_seq - max_new, size=n_requests)
    budgets = prompts + max_new
    pages_needed = -(-budgets // page)
    order = np.arange(n_requests)                            # arrival order
    cum = np.cumsum(pages_needed[order])
    paged_live = int(np.searchsorted(cum, pages_total, side="right"))
    ratio = paged_live / max(1, contig_slots)
    table = {
        "model": cfg.name, "kv_bytes_per_token": int(bpt),
        "cache_mem_bytes": mem, "max_seq": max_seq, "page_size": page,
        "contiguous_slots": int(contig_slots),
        "pages_total": int(pages_total),
        "mean_request_budget_tokens": float(budgets.mean()),
        "paged_live_requests": paged_live,
        "capacity_ratio_equal_mem": float(ratio),
    }
    print(f"\n# paged_capacity (equal cache memory {mem_gib:.0f} GiB, "
          f"{cfg.name}, max_seq {max_seq}, page {page})")
    print(f"contiguous {contig_slots} slots vs paged {paged_live} live "
          f"requests (mean budget {budgets.mean():.0f} toks) -> "
          f"{ratio:.2f}x capacity")
    print(f"[{'PASS' if ratio >= 1.5 else 'FAIL'}] paged capacity >= 1.5x "
          "contiguous at equal cache memory")
    return table


def serving_paged_bench(seed: int = 0):
    """Real paged-vs-contiguous runs on the smoke arch at EQUAL KV memory
    (128 cache token-rows each): the contiguous engine fits 2 full-max_seq
    slots; the paged engine spends the same rows on 16 shared pages across
    6 slots, so short-budget requests stack 3x deeper. Reports peak live
    concurrency, bit-exactness of every request against the contiguous
    reference, and the batched-vs-sequential admission latency of a burst."""
    import time

    import numpy as np

    from repro.configs.base import get_config
    from repro.serving import EngineConfig

    cfg = get_config("qwen2-0.5b-smoke")
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(6, 11))).tolist()
               for _ in range(10)]

    def run(paged: bool, params=None):
        kw = (dict(batch_size=6, page_size=8, n_pages=17) if paged
              else dict(batch_size=2))
        eng = EngineConfig(max_seq=64, chunk=8, seed=seed,
                           **kw).build(cfg, params=params)
        for p in prompts:
            eng.submit(p, max_new=6)
        peak = 0
        while eng.pending:
            eng.step()
            peak = max(peak, int(eng.live.sum()))
        toks = [eng.finished[r].tokens for r in sorted(eng.finished)]
        return eng, peak, toks

    ref, peak_c, toks_c = run(False)
    got, peak_p, toks_p = run(True, params=ref.params)
    exact = toks_c == toks_p

    # admission latency: a 4-request burst admitted one-per-step vs in one
    # stacked chunk call (same params, fresh caches)
    def admit_burst(admit_k):
        eng = EngineConfig(max_seq=64, batch_size=4, chunk=8,
                           admit_k=admit_k).build(cfg, params=ref.params)
        for p in prompts[:4]:
            eng.submit(p, max_new=2)
        t0 = time.perf_counter()
        while eng.queue or any(s is not None for s in eng.slot_req):
            eng.step()
            if eng.admissions >= 4:
                break
        wall = time.perf_counter() - t0
        eng.run()
        return wall, eng.admit_rounds, eng.prefill_s

    seq_s, seq_rounds, seq_prefill = admit_burst(1)
    bat_s, bat_rounds, bat_prefill = admit_burst(0)
    res = {
        "capacity": paged_capacity_table(),
        "trace": {
            "requests": len(prompts),
            "peak_live_contiguous": peak_c, "peak_live_paged": peak_p,
            "equal_mem_token_rows": 2 * 64,
            "bit_exact_vs_contiguous": bool(exact),
        },
        "admission": {
            "burst_requests": 4,
            "sequential_rounds": seq_rounds, "batched_rounds": bat_rounds,
            "sequential_admit_s": seq_s, "batched_admit_s": bat_s,
            "sequential_prefill_s": seq_prefill,
            "batched_prefill_s": bat_prefill,
        },
    }
    print(f"\n# serving_paged (equal-memory smoke run)")
    print(f"peak live: contiguous {peak_c} vs paged {peak_p} "
          f"(bit-exact: {exact})")
    print(f"admission burst of 4: {seq_rounds} rounds "
          f"{seq_s * 1e3:.1f}ms sequential vs {bat_rounds} round(s) "
          f"{bat_s * 1e3:.1f}ms batched")
    ok = exact and peak_p > peak_c and bat_rounds < seq_rounds
    print(f"[{'PASS' if ok else 'FAIL'}] paged run exact, deeper "
          "concurrency, batched admission in fewer stacked calls")
    return res


def serving_chaos_bench(n_requests: int = 8, slots: int = 2,
                        max_new: int = 8, seed: int = 0,
                        chaos_seed: int = 0):
    """Chaos trace through the REAL engine: the same Poisson-arrival
    workload run fault-free and then under a seeded fault schedule
    (injected step crashes + NaN logit rows + latency spikes) with
    snapshot/restore recovery. The robustness contract: every request
    reaches a terminal status, every non-quarantined token stream is
    bit-identical to the fault-free run with zero lost and zero duplicated
    emissions (exactly-once), and p99 TTFT under faults stays within a
    bounded factor of fault-free. Uses the non-MoE smoke arch so greedy
    decode is batch-composition independent (bit-exact replay)."""
    import tempfile
    import time

    import numpy as np

    from repro.configs.base import get_config
    from repro.serving import EngineConfig, FaultInjector, FaultPlan

    cfg = get_config("qwen2-0.5b-smoke")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(2.0, size=n_requests)).astype(int)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(3, 13))).tolist()
               for _ in range(n_requests)]

    def run_trace(params=None, faults=None, snapshot_dir=None):
        emissions = []
        ec = EngineConfig(max_seq=64, batch_size=slots, seed=seed, chunk=8,
                          page_size=8, snapshot_dir=snapshot_dir,
                          snapshot_every=2, max_restarts=16)
        eng = ec.build(cfg, params=params, faults=faults,
                       on_token=lambda r, i, t: emissions.append((r, i, t)))
        t0 = time.perf_counter()
        nxt = 0
        rids = []
        while nxt < n_requests or eng.pending:
            while nxt < n_requests and arrivals[nxt] <= eng.decode_steps:
                rids.append(eng.submit(prompts[nxt], max_new=max_new))
                nxt += 1
            if not eng.pending:                  # idle gap in the trace
                rids.append(eng.submit(prompts[nxt], max_new=max_new))
                nxt += 1
            eng.step()
        wall = time.perf_counter() - t0
        if faults is not None:
            faults.release_all(eng)
        return eng, rids, emissions, wall

    ref, ref_rids, _, ref_wall = run_trace()
    ref_toks = {rid: list(ref.finished[rid].tokens) for rid in ref_rids}
    ref_ttfts = [ref.finished[r].ttft_s for r in ref_rids]

    plan = FaultPlan.poisson(chaos_seed, horizon=96, crash_rate=0.08,
                             nan_rate=0.12, spike_rate=0.1, spike_s=0.005)
    inj = FaultInjector(plan)
    with tempfile.TemporaryDirectory(prefix="repro_chaos_") as snap:
        eng, rids, emissions, wall = run_trace(params=ref.params,
                                               faults=inj,
                                               snapshot_dir=snap)

    terminal = all(eng.finished[r].done for r in rids)
    identical = all(
        eng.finished[r].tokens == ref_toks[r]
        or (eng.finished[r].status.value == "quarantined"
            and eng.finished[r].tokens == ref_toks[r][:len(
                eng.finished[r].tokens)])
        for r in rids)
    seen = set()
    dup = 0
    for r, i, _ in emissions:
        dup += (r, i) in seen
        seen.add((r, i))
    lost = sum((r, i) not in seen for r in rids
               for i in range(len(eng.finished[r].tokens)))
    ttfts = [eng.finished[r].ttft_s for r in rids]
    p99 = float(np.percentile(ttfts, 99))
    p99_ref = float(np.percentile(ref_ttfts, 99))
    factor = p99 / max(p99_ref, 1e-9)
    res = {
        "n_requests": n_requests, "slots": slots,
        "injected": dict(inj.counts), "fault_plan": plan.summary(),
        "failures": eng.failures, "recoveries": eng.recoveries,
        "quarantined": eng.quarantined,
        "all_terminal": bool(terminal),
        "streams_bit_identical": bool(identical),
        "lost_tokens": int(lost), "duplicated_tokens": int(dup),
        "ttft_p99_s_clean": p99_ref, "ttft_p99_s_faulted": p99,
        "ttft_p99_factor": float(factor),
        "wall_s_clean": float(ref_wall), "wall_s_faulted": float(wall),
    }
    print(f"\n# serving_chaos (seeded fault schedule, {slots} slots, "
          f"{n_requests} requests)")
    print(f"injected {inj.counts} -> {eng.failures} failures / "
          f"{eng.recoveries} recoveries, {eng.quarantined} quarantined")
    print(f"terminal {terminal}, bit-identical {identical}, "
          f"lost {lost} dup {dup}, ttft p99 {p99*1e3:.1f}ms vs clean "
          f"{p99_ref*1e3:.1f}ms ({factor:.1f}x)")
    ok = terminal and identical and lost == 0 and dup == 0
    print(f"[{'PASS' if ok else 'FAIL'}] chaos trace exactly-once, "
          "all-terminal, bit-identical streams")
    return res


def serving_disagg_bench(n_requests: int = 10, max_new: int = 8,
                         seed: int = 0):
    """Disaggregated prefill/decode vs the shared engine at EQUAL total
    slots (4 shared vs 2 prefill + 2 decode), on a prefill-heavy
    mixed-length Poisson trace. Time is a virtual tick clock (+1 per
    scheduler step) so TTFT measures scheduling structure, not host
    noise. Four contracts, each a named gate:

    * every token stream is bit-exact vs the shared single engine;
    * mean TTFT (ticks) is STRICTLY below the shared engine's — prefill
      admission no longer waits on decode slot turnover;
    * a seeded single-worker crash (one decode loss, one prefill loss)
      recovers exactly-once across the handoff boundary: zero lost /
      duplicated emissions, streams identical to the clean disagg run;
    * migration is bounded: pages moved == content pages of each prompt
      (no tail-budget copies) and decode workers run ZERO prefill tokens
      (pages migrate, requests are never re-prefilled)."""
    import tempfile

    import numpy as np

    from repro.configs.base import get_config
    from repro.serving import EngineConfig, FaultInjector, FaultPlan
    from repro.serving.paged_cache import pages_for

    cfg = get_config("qwen2-0.5b-smoke")
    rng = np.random.default_rng(seed)
    # prefill-heavy mix: prompts 8-32 toks dwarf the 8-token decode budget
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(8, 33))).tolist()
               for _ in range(n_requests)]
    arrivals = np.cumsum(rng.exponential(1.5, size=n_requests)).astype(int)

    class Ticks:
        """Virtual clock: 1.0 per scheduler step, shared by engine(s)."""

        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

    def run_trace(build):
        emissions = []
        clock = Ticks()
        eng = build(clock, lambda r, i, t: emissions.append((r, i, t)))
        rids, nxt = [], 0
        while nxt < n_requests or eng.pending:
            while nxt < n_requests and arrivals[nxt] <= clock.t:
                rids.append(eng.submit(prompts[nxt], max_new=max_new))
                nxt += 1
            if not eng.pending and nxt < n_requests:  # idle gap in trace
                rids.append(eng.submit(prompts[nxt], max_new=max_new))
                nxt += 1
            eng.step()
            clock.t += 1.0
        return eng, rids, emissions

    shared_ec = EngineConfig(max_seq=64, batch_size=4, chunk=8, page_size=8,
                             seed=seed)
    shared, s_rids, _ = run_trace(
        lambda c, cb: shared_ec.build(cfg, clock=c, on_token=cb))
    params = shared.params

    dis_ec = EngineConfig(max_seq=64, batch_size=2, chunk=8, page_size=8,
                          seed=seed, disagg=True, prefill_workers=1,
                          decode_workers=1, prefill_slots=2, decode_slots=2)
    router, d_rids, d_emit = run_trace(
        lambda c, cb: dis_ec.build(cfg, params=params, clock=c, on_token=cb))

    shared_toks = {r: list(shared.finished[r].tokens) for r in s_rids}
    dis_toks = {r: list(router.finished[r].tokens) for r in d_rids}
    exact = shared_toks == dis_toks

    def ttfts(eng, rids):
        return [eng.finished[r].ttft_s for r in rids
                if eng.finished[r].first_token_t > 0]

    tt_s, tt_d = ttfts(shared, s_rids), ttfts(router, d_rids)
    mean_s, mean_d = float(np.mean(tt_s)), float(np.mean(tt_d))
    p99_s = float(np.percentile(tt_s, 99))
    p99_d = float(np.percentile(tt_d, 99))

    s = router.summary()
    expected_pages = sum(pages_for(len(p), router.page_size)
                         for p in prompts)
    decode_prefill = sum(w.prefill_tokens for w in router.decodes)
    migration_ok = (s["migrations"] == n_requests
                    and s["pages_moved"] == expected_pages
                    and decode_prefill == 0)

    # seeded single-worker crashes: one decode loss mid-trace, one
    # prefill loss later — exactly-once must hold across the handoff
    plan = FaultPlan(crash_workers={5: ("decode", 0), 11: ("prefill", 0)})
    with tempfile.TemporaryDirectory(prefix="repro_disagg_") as snap:
        crash_ec = EngineConfig(
            max_seq=64, batch_size=2, chunk=8, page_size=8, seed=seed,
            disagg=True, prefill_workers=1, decode_workers=1,
            prefill_slots=2, decode_slots=2, snapshot_dir=snap,
            snapshot_every=2, max_restarts=16, recover=True)
        injectors = {t: FaultInjector(plan, role=t)
                     for t in crash_ec.worker_targets()}
        crashed, c_rids, c_emit = run_trace(
            lambda c, cb: crash_ec.build(cfg, params=params, clock=c,
                                         on_token=cb, faults=injectors))
    injected = sum(inj.counts["crash"] for inj in injectors.values())
    crash_toks = {r: list(crashed.finished[r].tokens) for r in c_rids}
    seen, dup = set(), 0
    for r, i, _ in c_emit:
        dup += (r, i) in seen
        seen.add((r, i))
    lost = sum((r, i) not in seen for r in c_rids
               for i in range(len(crashed.finished[r].tokens)))
    terminal = all(crashed.finished[r].done for r in c_rids)
    crash_exact = crash_toks == dis_toks

    res = {
        "n_requests": n_requests, "total_slots": 4,
        "shared_slots": 4, "prefill_slots": 2, "decode_slots": 2,
        "trace": {
            "bit_exact_vs_shared_engine": bool(exact),
            "mixed_prompt_lens": sorted(len(p) for p in prompts),
        },
        "ttft": {
            "shared_mean_ticks": mean_s, "disagg_mean_ticks": mean_d,
            "shared_p99_ticks": p99_s, "disagg_p99_ticks": p99_d,
            "disagg_below_shared": bool(mean_d < mean_s),
        },
        "migration": {
            "migrations": int(s["migrations"]),
            "pages_moved": int(s["pages_moved"]),
            "expected_content_pages": int(expected_pages),
            "decode_worker_prefill_tokens": int(decode_prefill),
            "remigrations": int(s["remigrations"]),
            "bounded": bool(migration_ok),
        },
        "crash": {
            "plan": {str(t): f"{r}{i}" for t, (r, i)
                     in plan.crash_workers.items()},
            "injected_crashes": int(injected),
            "recoveries": int(crashed.recoveries),
            "failures": int(crashed.failures),
            "remigrations": int(crashed.remigrations),
            "duplicate_handoffs": int(crashed.duplicate_handoffs),
            "all_terminal": bool(terminal),
            "streams_bit_identical": bool(crash_exact),
            "lost_tokens": int(lost), "duplicated_tokens": int(dup),
        },
    }
    print(f"\n# serving_disagg (1x2 prefill -> 1x2 decode vs shared 4-slot, "
          f"{n_requests} mixed-length requests)")
    print(f"bit-exact vs shared: {exact}; ttft mean {mean_d:.1f} ticks "
          f"disagg vs {mean_s:.1f} shared (p99 {p99_d:.0f} vs {p99_s:.0f})")
    print(f"migration: {s['migrations']} handoffs, {s['pages_moved']} pages "
          f"(expected {expected_pages}), decode prefill toks "
          f"{decode_prefill}")
    print(f"crash run: {injected} injected -> {crashed.recoveries} "
          f"recoveries, lost {lost} dup {dup}, bit-identical {crash_exact}")
    ok = (exact and mean_d < mean_s and migration_ok and terminal
          and crash_exact and lost == 0 and dup == 0)
    print(f"[{'PASS' if ok else 'FAIL'}] disagg bit-exact, lower TTFT, "
          "bounded migration, exactly-once under single-worker crashes")
    return res


def serving_bench():
    """The serving figure set: modeled decode-plan quality, a real
    Poisson-trace run through the continuous-batching engine, the
    paged-cache memory-headroom / admission figures, the chaos
    fault-recovery figure, and the disaggregated prefill/decode
    topology figure."""
    return {"decode_plans": serving_decode_plan_table(),
            "trace": serving_trace_bench(),
            "paged": serving_paged_bench(),
            "chaos": serving_chaos_bench(),
            "disagg": serving_disagg_bench()}


def _jsonable(obj):
    """Figures return numpy scalars/tuple keys — normalize for json.dump."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item"):
        return obj.item()
    return obj


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan", metavar="CACHE", default=None,
                    help="run with schedules resolved from this plan cache")
    ap.add_argument("--tune", action="store_true",
                    help="populate a plan cache with model-backed tuning")
    ap.add_argument("--hw", default="tpu_v5e")
    ap.add_argument("--out", default=None,
                    help="--tune output path (default plans/<hw>.json)")
    ap.add_argument("--M", type=int, nargs="*", default=[1024, 4096, 16384])
    ap.add_argument("--ep", type=int, default=8)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write figures + kernel microbenchmarks + "
                         "modeled hot-path HBM bytes as machine-readable "
                         "JSON")
    args = ap.parse_args(argv)

    if args.tune:
        import os
        out = args.out or os.path.join("plans", f"{args.hw}.json")
        return run_tune(args.hw, out, args.M, args.ep)
    if args.plan is not None:
        return run_with_plan(args.plan, args.hw, args.M, args.ep)

    from benchmarks import figures
    results = {}
    for fn in figures.ALL:
        results[fn.__name__] = fn()
    fails = validate(results)
    if args.json:
        import json as _json
        payload = {
            "figures": _jsonable(results),
            "micro": _jsonable(kernel_microbench()),
            "hbm_hot_path": _jsonable(hbm_hot_path_table()),
            "bwd_overlap": _jsonable(bwd_overlap_table()),
            "whole_graph": _jsonable(whole_graph_table()),
            "hier_transport": _jsonable(hier_transport_table()),
            "serving": _jsonable(serving_bench()),
            "validation_failures": fails,
        }
        with open(args.json, "w") as f:
            _json.dump(payload, f, indent=1)
        print(f"\nwrote {args.json}")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
