"""Benchmark harness: one function per paper table/figure (simulator-driven
H100/L20 validation) + the TPU roofline summary from the dry-run artifacts.

Prints each figure's CSV, then a validation block checking the headline
numbers against the bands the paper reports. Exit code reflects validation.

Run:  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys


def validate(results) -> int:
    checks = []

    def chk(name, cond, detail):
        checks.append((name, bool(cond), detail))

    r = results["fig1a_breakdown"]
    chk("fig1a comm share ~47%", 0.25 <= r["avg_comm_share"] <= 0.65,
        f"avg={r['avg_comm_share']:.2f} (paper 0.47)")

    r = results["fig9_end_to_end"]
    chk("fig9 e2e speedup ~1.71x", 1.25 <= r["e2e_avg_speedup"] <= 2.2,
        f"avg={r['e2e_avg_speedup']:.2f} (paper 1.71)")

    r = results["fig10_single_layer"]
    chk("fig10 layer speedup ~1.96x", 1.4 <= r["layer_avg_speedup"] <= 2.6,
        f"avg={r['layer_avg_speedup']:.2f} (paper 1.96)")
    chk("fig10 layer speedup band", r["layer_min"] >= 1.0,
        f"min={r['layer_min']:.2f} (paper min 1.28)")

    h = results["fig11_latency_hiding"]["hiding"]
    chk("fig11 comet hides most latency", h["comet"] >= 0.75,
        f"comet={h['comet']:.2f} (paper 0.865)")
    chk("fig11 ordering comet>tutel>fastermoe",
        h["comet"] > h["tutel"] > h["fastermoe"],
        f"{h['comet']:.2f} > {h['tutel']:.2f} > {h['fastermoe']:.2f} "
        "(paper 0.865/0.686/0.292)")

    r = results["fig12_parallelism"]
    chk("fig12 comet robust across EPxTP",
        r["degrade_comet"] < r["degrade_base"],
        f"comet {r['degrade_comet']:.2f}x vs baseline "
        f"{r['degrade_base']:.2f}x over the TP sweep")

    r = results["fig13_experts_topk"]
    chk("fig13 speedup band ~1.16-1.83x",
        r["etopk_min"] >= 0.95 and r["etopk_max"] <= 3.5,
        f"range {r['etopk_min']:.2f}-{r['etopk_max']:.2f}")

    r = results["fig14_imbalance_and_l20"]
    chk("fig14 imbalance prolongs all systems", r["imb_monotone"], "")
    chk("fig14 comet best under imbalance", r["comet_best_under_imbalance"],
        "")
    chk("fig14 L20 speedup ~1.19-1.46x", 1.0 <= r["l20_avg_speedup"] <= 1.9,
        f"avg={r['l20_avg_speedup']:.2f}")

    r = results["roofline_summary"]
    chk("roofline artifacts present", r["n_cells"] >= 30,
        f"{r['n_cells']} cells")

    print("\n#### validation vs paper claims ####")
    fails = 0
    for name, ok, detail in checks:
        print(f"[{'PASS' if ok else 'FAIL'}] {name} {detail}")
        fails += 0 if ok else 1
    print(f"\n{len(checks) - fails}/{len(checks)} validation checks passed")
    return fails


def main() -> int:
    from benchmarks import figures
    results = {}
    for fn in figures.ALL:
        results[fn.__name__] = fn()
    return 1 if validate(results) else 0


if __name__ == "__main__":
    sys.exit(main())
