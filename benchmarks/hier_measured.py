"""Interpret-measured side of the hier-transport BENCH figure (PR 9).

Runs BOTH ring transports (flat ``transport_comet_blocks`` and the
two-level ``transport_comet_hier``) for real on 8 forced host devices with
the ppermute CENSUS enabled, then prices every hop the executed program
actually performed: a hop is inter-class iff ANY of its (src, dst) pairs
crosses a node boundary of the ``intra_group``-wide nodes (a synchronous
collective completes at its slowest link), bytes are the census payload
bytes (so the wire format shows up in the measured traffic), and the
per-class rate comes from the SAME topology descriptor the analytical
model uses. The priced hop profiles feed ``exposed_comm_from_hops`` — the
three-resource pipeline — so "measured" differs from "modeled" exactly in
where the hop times come from: executed bytes/permutations vs closed-form
chunk sizes.

The wire-format acceptance rows ride the same executions: bf16 / fp8
outputs vs the fp32 wire (documented tolerances, fp32 accumulation) and
the exact-rotation-determinism bit check on the encoded payloads.

Prints ONE JSON object on stdout; ``benchmarks.run:hier_transport_table``
parses it and ``check_bench.py`` gates it. Must run in its own process
(sets XLA_FLAGS before importing jax); invoke as
``python -m benchmarks.hier_measured``.
"""
import json
import os
import sys


def _hop_time(hw, entry, intra_group, etp, link_class_bw):
    """Price one censused ppermute: slowest-link class + payload bytes."""
    cls = "intra"
    for src, dst in entry["pairs"]:
        if (src // etp) // intra_group != (dst // etp) // intra_group:
            cls = "inter"
            break
    return hw.hop_latency_s + entry["bytes"] / link_class_bw(hw, cls)


def main() -> int:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import adaptive as A
    from repro.core import transport as T
    from repro.parallel.compat import make_mesh, shard_map, use_mesh
    from repro.parallel.mesh import AxisCtx, P

    ep, etp = 8, 1
    hw = A.H100_CROSSNODE
    ig = A.legalize_intra_group(ep, hw.intra_group)
    E_loc, C, d, f = 1, 64, 128, 256
    activation = "swiglu"

    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    send_g = jax.random.normal(ks[0], (ep, ep, E_loc, C, d), jnp.float32)
    w_g = {"w_gate": jax.random.normal(ks[1], (ep, E_loc, d, f),
                                       jnp.float32) * 0.05,
           "w_up": jax.random.normal(ks[2], (ep, E_loc, d, f),
                                     jnp.float32) * 0.05,
           "w_down": jax.random.normal(ks[3], (ep, E_loc, f, d),
                                       jnp.float32) * 0.05}
    mesh = make_mesh((ep,), ("model",))
    ctx = AxisCtx(mesh=mesh, dp_axes=(), model_axis="model", ep=ep, etp=etp)

    def run(impl, wire, census):
        """Execute one transport under shard_map; census fills at trace."""
        def body(send_l, wg, wu, wd):
            w = {"w_gate": wg[0], "w_up": wu[0], "w_down": wd[0]}
            if impl == "comet_hier":
                # hier already returns destination order (rot=None)
                blocks, _ = T.transport_comet_hier(
                    ctx, send_l[0], w, activation, intra_group=ig,
                    wire_dtype=wire, custom_vjp=False, census=census)
                out = blocks[0]
            else:
                # flat slot s holds destination (rot - s) % ep; reorder to
                # destination order so the parity check compares like slots
                blocks, rot = T.transport_comet_blocks(
                    ctx, send_l[0], w, activation, custom_vjp=False,
                    census=census)
                out = jnp.take(blocks[0], (rot - jnp.arange(ep)) % ep,
                               axis=0)
            return out[None]
        spec = P("model")
        fn = shard_map(body, mesh=mesh,
                       in_specs=(spec, spec, spec, spec),
                       out_specs=spec, check_vma=False)
        with use_mesh(mesh):
            out = fn(send_g, w_g["w_gate"], w_g["w_up"], w_g["w_down"])
        return np.asarray(jax.block_until_ready(out))

    # ---- measured exposed comm: flat vs hier at the fp32 wire -----------
    cen_flat, cen_hier = [], []
    y_flat = run("comet", "fp32", cen_flat)
    y_hier = {"fp32": run("comet_hier", "fp32", cen_hier)}

    # one macro-step's GEMM time from the same analytical terms the model
    # uses, at THIS problem's chunk shape — shared by both transports, so
    # the flat/hier comparison isolates the ring topology + wire bytes
    s_eq = A.MoEShape(M=ep * C, N=d, K=f, E=ep * E_loc, topk=1, ep=ep,
                      etp=etp, bytes_per_elt=4)
    t_comp = A.layer_times(hw, s_eq)["t_chunk_compute"]

    def exposed(census):
        disp = [e for e in census if e["op"] == "disp"]
        comb = [e for e in census if e["op"] == "comb"]
        hop_in = [0.0] + [_hop_time(hw, e, ig, etp, A.link_class_bw)
                          for e in disp]
        hop_out = [0.0] + [_hop_time(hw, e, ig, etp, A.link_class_bw)
                           for e in comb]
        n_inter = sum(
            1 for e in disp + comb
            if any((src // etp) // ig != (dst // etp) // ig
                   for src, dst in e["pairs"]))
        return {"exposed_s": A.exposed_comm_from_hops(hop_in, hop_out,
                                                      t_comp, 1),
                "hops": len(disp) + len(comb), "inter_hops": n_inter,
                "intra_hops": len(disp) + len(comb) - n_inter,
                "bytes": sum(e["bytes"] for e in disp + comb)}

    measured = {"flat": exposed(cen_flat), "hier": exposed(cen_hier),
                "t_comp_s": t_comp}
    if T.wire_dtype_supported("bf16"):
        cen_bf16 = []
        y_bf16 = run("comet_hier", "bf16", cen_bf16)
        measured["hier_bf16"] = exposed(cen_bf16)
    # flat and hier reroute the same traffic — outputs must agree exactly
    parity = float(np.max(np.abs(y_flat - y_hier["fp32"]))
                   / (np.max(np.abs(y_flat)) + 1e-9))

    # ---- wire tolerance rows (fp32 accumulation documented bounds) ------
    wire = {}
    ref = np.max(np.abs(y_hier["fp32"])) + 1e-9
    for wd, tol in (("bf16", 2e-2), ("fp8_e4m3", 2e-1)):
        if not T.wire_dtype_supported(wd):
            wire[wd] = {"available": False, "tol": tol}
            continue
        y = y_bf16 if wd == "bf16" else run("comet_hier", wd, None)
        wire[wd] = {"available": True, "tol": tol,
                    "max_rel_err": float(np.max(np.abs(y - y_hier["fp32"]))
                                         / ref)}

    # ---- exact rotation determinism of the encoded payloads -------------
    deterministic = True
    for wd in ("bf16", "fp8_e4m3"):
        if not T.wire_dtype_supported(wd):
            continue
        pay, sc = T._wire_encode(send_g[0], wd, per_chunk=True)
        for rot in (1, 3, 5):
            pay_r, sc_r = T._wire_encode(jnp.roll(send_g[0], rot, axis=0),
                                         wd, per_chunk=True)
            same = np.array_equal(
                np.asarray(pay_r).view(np.uint8),
                np.asarray(jnp.roll(pay, rot, axis=0)).view(np.uint8))
            if sc is not None:
                same = same and np.array_equal(
                    np.asarray(sc_r),
                    np.asarray(jnp.roll(sc, rot, axis=0)))
            deterministic = deterministic and same

    json.dump({"measured": measured, "flat_hier_parity_rel": parity,
               "wire": wire, "rotation_deterministic": deterministic,
               "ep": ep, "intra_group": ig}, sys.stdout)
    return 0


if __name__ == "__main__":
    sys.exit(main())
